//! Wire-layer tier: loopback integration + parser property tests for
//! `coordinator::http`.
//!
//! The serving invariant extends across the wire — SSE-reassembled
//! token streams must be **bitwise identical** to `serve_batch` output
//! for the same (prompt, budget), across admission policies and lane
//! counts — and every externally-reachable behavior is pinned here:
//! parsing (segmentation invariance, pipelining, garbage), shedding
//! (429 + `Retry-After`, connection reusable), deadlines (final error
//! event, lane retired leak-free) and graceful drain (in-flight
//! completes, new connections refused). The client side is raw
//! `std::net` — no HTTP library on either end of the socket.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use heapr::coordinator::http::{Parse, RequestParser, MAX_HEAD_BYTES};
use heapr::coordinator::{
    HttpOpts, HttpServeReport, HttpServer, PoissonSchedule, Request, ServeMetrics, Server,
};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::util::json::Json;
use heapr::util::pool;
use heapr::util::prop;

const DIR: &str = "artifacts/tiny";

struct Shared {
    engine: Engine,
    params: ParamStore,
}

// SAFETY: access is serialized through the Mutex (see integration.rs).
unsafe impl Send for Shared {}

fn shared() -> &'static Mutex<Shared> {
    static CTX: OnceLock<Mutex<Shared>> = OnceLock::new();
    CTX.get_or_init(|| {
        let engine = Engine::open(DIR).expect("open tiny preset");
        let params = ParamStore::init(&engine.manifest, 11);
        Mutex::new(Shared { engine, params })
    })
}

fn base_prompt() -> Vec<i32> {
    let g = Grammar::standard();
    let docs = g.corpus("wiki", 3, 4000);
    Split::from_docs(&docs, 64).chunks[0].clone()
}

/// `serve_batch` reference tokens for one (prompt, budget).
fn reference_tokens(ctx: &Shared, prompt: &[i32], budget: usize) -> Vec<i32> {
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let resp = server.serve_batch(&[Request::new(0, prompt.to_vec(), budget)]).unwrap();
    resp.into_iter().next().unwrap().tokens
}

/// Pick a prompt whose natural generation under `budget` runs long
/// enough (several decode steps) to hold a lane busy while other
/// requests arrive — chosen deterministically from the reference path,
/// so the robustness tests never race a surprise instant-EOS.
fn long_running_spec(ctx: &Shared, budget: usize) -> (Vec<i32>, Vec<i32>) {
    let base = base_prompt();
    let mut best: (Vec<i32>, Vec<i32>) = (Vec::new(), Vec::new());
    for plen in [8usize, 12, 16, 20, 24, 32] {
        let prompt = base[..plen].to_vec();
        let tokens = reference_tokens(ctx, &prompt, budget);
        if tokens.len() > best.1.len() {
            best = (prompt, tokens);
        }
        if best.1.len() >= 16 {
            break;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Raw std::net HTTP client
// ---------------------------------------------------------------------------

fn connect(addr: SocketAddr) -> TcpStream {
    let stream = TcpStream::connect(addr).expect("connect to loopback server");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn read_until(stream: &mut TcpStream, buf: &mut Vec<u8>, pat: &[u8]) -> usize {
    let mut tmp = [0u8; 2048];
    loop {
        if let Some(p) = find(buf, pat) {
            return p;
        }
        match stream.read(&mut tmp) {
            Ok(0) => panic!(
                "connection closed while waiting for {:?}; got {:?}",
                String::from_utf8_lossy(pat),
                String::from_utf8_lossy(buf)
            ),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => panic!("client read failed: {e}"),
        }
    }
}

fn read_at_least(stream: &mut TcpStream, buf: &mut Vec<u8>, need: usize) {
    let mut tmp = [0u8; 2048];
    while buf.len() < need {
        match stream.read(&mut tmp) {
            Ok(0) => panic!("connection closed {} bytes short", need - buf.len()),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => panic!("client read failed: {e}"),
        }
    }
}

type Headers = Vec<(String, String)>;

/// Read one response head; returns (status, headers, leftover bytes
/// already read past the head).
fn read_head(stream: &mut TcpStream) -> (u16, Headers, Vec<u8>) {
    let mut buf = Vec::new();
    let head_end = read_until(stream, &mut buf, b"\r\n\r\n");
    let head = String::from_utf8(buf[..head_end].to_vec()).expect("response head is UTF-8");
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap();
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"));
    let headers: Headers = lines
        .map(|l| {
            let (k, v) = l.split_once(':').unwrap_or_else(|| panic!("bad header {l:?}"));
            (k.trim().to_ascii_lowercase(), v.trim().to_string())
        })
        .collect();
    (status, headers, buf[head_end + 4..].to_vec())
}

fn header<'h>(headers: &'h Headers, name: &str) -> Option<&'h str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// Read one response body (chunked or Content-Length framed), starting
/// from `rest` (bytes already read past the head).
fn read_body(stream: &mut TcpStream, headers: &Headers, mut rest: Vec<u8>) -> Vec<u8> {
    if header(headers, "transfer-encoding") == Some("chunked") {
        let mut body = Vec::new();
        loop {
            let line_end = read_until_buf(stream, &mut rest, b"\r\n");
            let size_hex = std::str::from_utf8(&rest[..line_end]).expect("chunk size is UTF-8");
            let size = usize::from_str_radix(size_hex, 16).expect("chunk size is hex");
            let need = line_end + 2 + size + 2;
            read_at_least(stream, &mut rest, need);
            body.extend_from_slice(&rest[line_end + 2..line_end + 2 + size]);
            rest.drain(..need);
            if size == 0 {
                return body;
            }
        }
    }
    let len: usize = header(headers, "content-length").map(|v| v.parse().unwrap()).unwrap_or(0);
    read_at_least(stream, &mut rest, len);
    rest.truncate(len);
    rest
}

// like read_until but over an existing buffer that may already match
fn read_until_buf(stream: &mut TcpStream, buf: &mut Vec<u8>, pat: &[u8]) -> usize {
    if let Some(p) = find(buf, pat) {
        return p;
    }
    read_until(stream, buf, pat)
}

/// Write a request, read one full response.
fn exchange(stream: &mut TcpStream, request: &[u8]) -> (u16, Headers, Vec<u8>) {
    stream.write_all(request).expect("client write");
    let (status, headers, rest) = read_head(stream);
    let body = read_body(stream, &headers, rest);
    (status, headers, body)
}

fn generate_req(prompt: &[i32], budget: usize, deadline_ms: Option<u64>) -> Vec<u8> {
    let toks: Vec<f64> = prompt.iter().map(|&t| t as f64).collect();
    let mut fields = vec![
        ("prompt", Json::arr_f64(&toks)),
        ("max_new_tokens", Json::n(budget as f64)),
    ];
    if let Some(ms) = deadline_ms {
        fields.push(("deadline_ms", Json::n(ms as f64)));
    }
    let body = Json::obj(fields).to_string();
    let mut req = format!(
        "POST /generate HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    req.extend_from_slice(body.as_bytes());
    req
}

#[derive(Debug, Clone, PartialEq)]
struct Event {
    id: u64,
    index: usize,
    token: i32,
    done: bool,
    error: Option<String>,
}

fn parse_events(body: &[u8]) -> Vec<Event> {
    let text = std::str::from_utf8(body).expect("SSE body is UTF-8");
    text.split("\n\n")
        .filter(|chunk| !chunk.is_empty())
        .map(|chunk| {
            let payload = chunk.strip_prefix("data: ").expect("SSE data line");
            let j = Json::parse(payload).expect("SSE event is JSON");
            Event {
                id: j.get("id").unwrap().as_usize().unwrap() as u64,
                index: j.opt("index").map(|x| x.as_usize().unwrap()).unwrap_or(0),
                token: j.opt("token").map(|x| x.as_f64().unwrap() as i32).unwrap_or(0),
                done: matches!(j.opt("done"), Some(Json::Bool(true))),
                error: j.opt("error").map(|e| e.as_str().unwrap().to_string()),
            }
        })
        .collect()
}

fn stream_tokens_of(events: &[Event]) -> Vec<i32> {
    events.iter().filter(|e| e.error.is_none()).map(|e| e.token).collect()
}

/// One request's stream must be internally coherent: a single id,
/// indexes 0..n in order, `done` exactly on the last event, no errors.
fn check_stream_shape(events: &[Event]) {
    assert!(!events.is_empty(), "stream carries at least one event");
    let id = events[0].id;
    for (i, ev) in events.iter().enumerate() {
        assert_eq!(ev.id, id, "one stream, one id");
        assert_eq!(ev.index, i, "index order");
        assert_eq!(ev.done, i + 1 == events.len(), "done on the last event only");
        assert!(ev.error.is_none(), "unexpected error event: {ev:?}");
    }
}

// ---------------------------------------------------------------------------
// Server harness
// ---------------------------------------------------------------------------

/// Run a live loopback server on the test thread (the scheduler borrows
/// the engine) while `client` drives it from a worker thread. The
/// shutdown flag is always raised when the client returns or panics, so
/// a failing assertion can never hang the drain.
fn with_server<T: Send + 'static>(
    ctx: &Shared,
    opts: HttpOpts,
    client: impl FnOnce(SocketAddr, Arc<AtomicBool>) -> T + Send + 'static,
) -> (HttpServeReport, ServeMetrics, T) {
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let http = HttpServer::bind(opts).unwrap();
    let addr = http.local_addr();
    let shutdown = http.shutdown_handle();
    let worker = pool::spawn_named("test-client", move || {
        let out = catch_unwind(AssertUnwindSafe(|| client(addr, shutdown.clone())));
        shutdown.store(true, Ordering::Release);
        out
    });
    let report = http.serve(&mut server).unwrap();
    let out = match worker.join() {
        Ok(Ok(out)) => out,
        Ok(Err(panic)) => resume_unwind(panic),
        Err(panic) => resume_unwind(panic),
    };
    (report, server.metrics.clone(), out)
}

// ---------------------------------------------------------------------------
// Wire-level equivalence (the PR 5 invariant, extended across the wire)
// ---------------------------------------------------------------------------

#[test]
fn wire_streams_match_serve_batch_across_policies_and_lanes() {
    let ctx = shared().lock().unwrap();
    let base = base_prompt();
    // staggered prompt lengths and budgets, as in the scheduler tier
    let specs: Vec<(Vec<i32>, usize)> =
        (0..6).map(|i| (base[..8 + 8 * (i % 3)].to_vec(), 2 + (i % 4) * 2)).collect();
    let want: Vec<Vec<i32>> = specs.iter().map(|(p, b)| reference_tokens(&ctx, p, *b)).collect();

    for group_extent in [false, true] {
        for lanes in [Some(1), None] {
            let opts = HttpOpts { max_queue: 0, lanes, group_extent, ..HttpOpts::default() };
            let specs_c = specs.clone();
            let (report, metrics, got) = with_server(&ctx, opts, move |addr, _sd| {
                // two concurrent connections, three keep-alive requests
                // each, so admission interleaves mid-decode on the wire
                let handles: Vec<_> = (0..2)
                    .map(|c| {
                        let mine: Vec<(Vec<i32>, usize)> =
                            specs_c.iter().skip(c).step_by(2).cloned().collect();
                        pool::spawn_named("wire-client", move || {
                            let mut conn = connect(addr);
                            mine.into_iter()
                                .map(|(prompt, budget)| {
                                    let (status, headers, body) =
                                        exchange(&mut conn, &generate_req(&prompt, budget, None));
                                    assert_eq!(status, 200);
                                    assert_eq!(
                                        header(&headers, "content-type"),
                                        Some("text/event-stream")
                                    );
                                    let events = parse_events(&body);
                                    check_stream_shape(&events);
                                    stream_tokens_of(&events)
                                })
                                .collect::<Vec<Vec<i32>>>()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
            });
            for (c, streams) in got.iter().enumerate() {
                for (k, tokens) in streams.iter().enumerate() {
                    let idx = c + 2 * k;
                    assert_eq!(
                        tokens, &want[idx],
                        "wire stream diverged from serve_batch \
                         (spec {idx}, group_extent {group_extent}, lanes {lanes:?})"
                    );
                }
            }
            assert_eq!(report.admitted, specs.len());
            assert_eq!(report.shed, 0);
            assert_eq!(report.responses.len(), specs.len());
            assert_eq!(metrics.requests, specs.len());
            assert_eq!(metrics.cancelled_requests, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// Parser property suite
// ---------------------------------------------------------------------------

fn gen_valid_request(g: &mut prop::Gen) -> Vec<u8> {
    let body_len = g.usize_in(0, 48);
    let body: Vec<u8> = (0..body_len).map(|_| g.usize_in(0, 255) as u8).collect();
    let path = ["/generate", "/healthz", "/a/b", "/"][g.usize_in(0, 3)];
    let method = ["GET", "POST", "PUT"][g.usize_in(0, 2)];
    let mut out =
        format!("{method} {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {body_len}\r\n\r\n")
            .into_bytes();
    out.extend_from_slice(&body);
    out
}

/// Wire-byte generator mixing well-formed requests, pipelined trains,
/// mutations, truncations and CRLF-rich byte soup.
fn gen_wire_bytes(g: &mut prop::Gen) -> Vec<u8> {
    match g.usize_in(0, 5) {
        kind @ 0..=2 => {
            let mut out = Vec::new();
            for _ in 0..=kind {
                out.extend_from_slice(&gen_valid_request(g));
            }
            out
        }
        3 => {
            let mut raw = gen_valid_request(g);
            let i = g.usize_in(0, raw.len() - 1);
            raw[i] = g.usize_in(0, 255) as u8;
            raw
        }
        4 => {
            let mut raw = gen_valid_request(g);
            let keep = g.usize_in(0, raw.len());
            raw.truncate(keep);
            raw
        }
        _ => {
            let n = g.usize_in(0, 160);
            const ALPHABET: &[u8] = b"GET POST/ HTTP1.:\r\n\x00\xffabc0987654321-";
            (0..n).map(|_| ALPHABET[g.usize_in(0, ALPHABET.len() - 1)]).collect()
        }
    }
}

/// Feed `raw` split at `cuts` and collect every parse result; a fatal
/// `Bad` ends the run (the connection would close there).
fn run_parser(raw: &[u8], cuts: &[usize]) -> Vec<Parse> {
    let mut sorted: Vec<usize> = cuts.iter().map(|&c| c.min(raw.len())).collect();
    sorted.sort_unstable();
    sorted.dedup();
    let mut parser = RequestParser::new();
    let mut out = Vec::new();
    let mut prev = 0;
    let mut segments: Vec<&[u8]> = Vec::new();
    for &c in &sorted {
        segments.push(&raw[prev..c]);
        prev = c;
    }
    segments.push(&raw[prev..]);
    for seg in segments {
        parser.feed(seg);
        loop {
            match parser.poll() {
                Parse::Pending => break,
                bad @ Parse::Bad(..) => {
                    out.push(bad);
                    return out;
                }
                ready => out.push(ready),
            }
        }
    }
    out
}

#[test]
fn parser_parse_is_invariant_under_read_segmentation() {
    prop::check(
        "http-parse-segmentation",
        250,
        |g| {
            let raw = gen_wire_bytes(g);
            let n_cuts = g.usize_in(0, 6);
            let cuts: Vec<usize> = (0..n_cuts).map(|_| g.usize_in(0, raw.len().max(1))).collect();
            (raw, cuts)
        },
        |(raw, cuts)| run_parser(raw, cuts) == run_parser(raw, &[]),
    );
}

#[test]
fn parser_never_panics_or_hangs_on_byte_soup() {
    prop::check("http-byte-soup", 300, gen_wire_bytes, |raw| {
        let mut parser = RequestParser::new();
        parser.feed(raw);
        // quiescence within a bounded number of polls: each poll either
        // consumes a request, turns terminal, or asks for more input —
        // anything else would be a busy-loop on the connection thread
        for _ in 0..=raw.len() {
            match parser.poll() {
                Parse::Pending | Parse::Bad(..) => return true,
                Parse::Ready(_) => {}
            }
        }
        false
    });
}

#[test]
fn parser_handles_torn_utf8_and_rejects_invalid_heads() {
    // valid multi-byte UTF-8 in the path, split mid-codepoint across
    // reads: the parser decodes only complete heads, so the parse holds
    let raw = "GET /g\u{00e9}n\u{00e9}ration HTTP/1.1\r\n\r\n".as_bytes().to_vec();
    let whole = run_parser(&raw, &[]);
    assert!(matches!(whole[0], Parse::Ready(_)), "{whole:?}");
    for cut in 1..raw.len() {
        assert_eq!(run_parser(&raw, &[cut]), whole, "torn at byte {cut}");
    }

    // invalid UTF-8 *in the head* is a clean 400, never a panic
    let bad = b"GET /\xff\xfe HTTP/1.1\r\n\r\n";
    assert!(
        matches!(run_parser(bad, &[]).last(), Some(Parse::Bad(400, _))),
        "invalid head bytes must 400"
    );

    // arbitrary bytes *in the body* are passed through untouched
    let mut req = b"POST /x HTTP/1.1\r\nContent-Length: 4\r\n\r\n".to_vec();
    req.extend_from_slice(&[0xff, 0x00, 0xc3, 0x28]);
    let got = run_parser(&req, &[]);
    let Some(Parse::Ready(parsed)) = got.first() else {
        panic!("body bytes broke the parse: {got:?}")
    };
    assert_eq!(parsed.body, [0xff, 0x00, 0xc3, 0x28]);
}

// ---------------------------------------------------------------------------
// Robustness: shedding, deadlines, drain, routing
// ---------------------------------------------------------------------------

#[test]
fn bounded_queue_sheds_with_retry_after_and_connection_survives() {
    let ctx = shared().lock().unwrap();
    let (prompt, natural) = long_running_spec(&ctx, 64);
    assert!(natural.len() >= 4, "need a prompt that decodes for a while");
    let budget = natural.len();
    let opts = HttpOpts { max_queue: 2, lanes: Some(1), ..HttpOpts::default() };
    let (p2, nat) = (prompt.clone(), natural.clone());
    let (report, metrics, ()) = with_server(&ctx, opts, move |addr, _sd| {
        let mut a1 = connect(addr);
        let mut a2 = connect(addr);
        let mut b = connect(addr);
        // occupy the lane and the queue: the SSE response head is
        // written only after admission, so reading it removes all
        // timing races from the 429 assertion
        a1.write_all(&generate_req(&p2, budget, None)).unwrap();
        let (s1, h1, rest1) = read_head(&mut a1);
        assert_eq!(s1, 200);
        a2.write_all(&generate_req(&p2, budget, None)).unwrap();
        let (s2, h2, rest2) = read_head(&mut a2);
        assert_eq!(s2, 200);
        // two in flight >= max_queue: b is shed, politely
        let (status, headers, _body) = exchange(&mut b, &generate_req(&p2, budget, None));
        assert_eq!(status, 429);
        assert_eq!(header(&headers, "retry-after"), Some("1"));
        // the shed connection is still usable immediately…
        let (status, _, _) = exchange(&mut b, b"GET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        // …and admissible again once the queue drains
        let t1 = stream_tokens_of(&parse_events(&read_body(&mut a1, &h1, rest1)));
        let t2 = stream_tokens_of(&parse_events(&read_body(&mut a2, &h2, rest2)));
        assert_eq!(t1, nat, "shedding must not perturb admitted streams");
        assert_eq!(t2, nat);
        let (status, _, body) = exchange(&mut b, &generate_req(&p2, budget, None));
        assert_eq!(status, 200);
        assert_eq!(stream_tokens_of(&parse_events(&body)), nat);
    });
    assert_eq!(report.shed, 1, "exactly one request was refused");
    assert_eq!(report.admitted, 3);
    assert_eq!(metrics.requests, 3);
    assert_eq!(metrics.cancelled_requests, 0);
}

#[test]
fn deadline_terminates_stream_and_retires_lane_leak_free() {
    let ctx = shared().lock().unwrap();
    let (prompt, natural) = long_running_spec(&ctx, 96);
    assert!(natural.len() >= 8, "need a long natural stream to cut short");
    let opts = HttpOpts { max_queue: 0, lanes: Some(1), ..HttpOpts::default() };
    let p2 = prompt.clone();
    let (report, metrics, events) = with_server(&ctx, opts, move |addr, _sd| {
        let mut conn = connect(addr);
        // a deadline far below the stream's natural duration
        let (status, _h, body) = exchange(&mut conn, &generate_req(&p2, 96, Some(1)));
        assert_eq!(status, 200);
        parse_events(&body)
    });
    let last = events.last().expect("stream carries at least the error event");
    assert_eq!(last.error.as_deref(), Some("deadline"), "stream ends in the error event");
    assert!(last.done, "the error event is terminal");
    assert!(
        stream_tokens_of(&events).len() < natural.len(),
        "deadline must cut the stream short of its natural length"
    );
    // the lane was retired through the normal path — counted as served
    // *and* as cancelled, its response recorded: nothing leaked
    assert_eq!(metrics.cancelled_requests, 1);
    assert_eq!(metrics.requests, 1);
    assert_eq!(report.admitted, 1);
    assert_eq!(report.responses.len(), 1);
    assert!(report.responses[0].tokens.len() < natural.len());
}

#[test]
fn graceful_drain_completes_in_flight_and_refuses_new_connections() {
    let ctx = shared().lock().unwrap();
    let (prompt, natural) = long_running_spec(&ctx, 48);
    assert!(natural.len() >= 4);
    let budget = natural.len();
    let opts = HttpOpts { max_queue: 0, lanes: Some(1), ..HttpOpts::default() };
    let (p2, nat) = (prompt.clone(), natural.clone());
    let (report, metrics, ()) = with_server(&ctx, opts, move |addr, shutdown| {
        let mut conn = connect(addr);
        conn.write_all(&generate_req(&p2, budget, None)).unwrap();
        let (status, headers, rest) = read_head(&mut conn);
        assert_eq!(status, 200);
        // drain starts while the stream is mid-flight
        shutdown.store(true, Ordering::Release);
        // new connections are refused once the listener closes (the
        // in-flight stream below is still open at this point)
        let give_up = Instant::now() + Duration::from_secs(10);
        loop {
            match TcpStream::connect(addr) {
                Err(_) => break, // refused — drain closed the listener
                Ok(extra) => drop(extra), // pre-drain backlog at worst
            }
            assert!(Instant::now() < give_up, "listener never closed during drain");
            std::thread::sleep(Duration::from_millis(10));
        }
        // the in-flight stream still completes, bit-exact
        let events = parse_events(&read_body(&mut conn, &headers, rest));
        check_stream_shape(&events);
        assert_eq!(stream_tokens_of(&events), nat, "drain must not perturb the stream");
    });
    assert_eq!(report.admitted, 1);
    assert_eq!(metrics.requests, 1);
    assert_eq!(metrics.cancelled_requests, 0, "drain finishes lanes, it does not cancel them");
}

#[test]
fn routing_and_protocol_errors_over_the_wire() {
    let ctx = shared().lock().unwrap();
    let opts = HttpOpts { max_queue: 0, ..HttpOpts::default() };
    let (_report, _metrics, ()) = with_server(&ctx, opts, move |addr, _sd| {
        let mut conn = connect(addr);
        let (s, _, body) = exchange(&mut conn, b"GET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!(s, 200);
        assert!(body.starts_with(b"{\"status\":\"ok\""), "{:?}", String::from_utf8_lossy(&body));
        // wrong method: 405 names the allowed one
        let (s, h, _) = exchange(&mut conn, b"PUT /generate HTTP/1.1\r\n\r\n");
        assert_eq!(s, 405);
        assert_eq!(header(&h, "allow"), Some("POST"));
        let (s, _, _) = exchange(&mut conn, b"GET /nope HTTP/1.1\r\n\r\n");
        assert_eq!(s, 404);
        // a bad JSON body is a 400 and the connection stays usable
        let (s, _, _) =
            exchange(&mut conn, b"POST /generate HTTP/1.1\r\nContent-Length: 3\r\n\r\n{{{");
        assert_eq!(s, 400);
        let (s, _, _) = exchange(&mut conn, b"GET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!(s, 200);
        // a malformed request line is fatal: 400, then the socket closes
        let mut broken = connect(addr);
        let (s, _, _) = exchange(&mut broken, b"BROKEN\r\n\r\n");
        assert_eq!(s, 400);
        match broken.read(&mut [0u8; 16]) {
            Ok(0) => {}
            other => panic!("fatal parse must close the connection, got {other:?}"),
        }
        // an oversized head answers 431 without waiting for a terminator
        let mut oversized = connect(addr);
        let mut big = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
        big.resize(MAX_HEAD_BYTES + 64, b'a');
        oversized.write_all(&big).unwrap();
        let (s, _, _) = read_head(&mut oversized);
        assert_eq!(s, 431);
    });
}

// ---------------------------------------------------------------------------
// Load-generator determinism
// ---------------------------------------------------------------------------

#[test]
fn poisson_schedule_is_pure_function_of_seed_across_thread_counts() {
    // serialized with the engine tests (set_threads swaps the global
    // pool; the shared lock is this file's serialization point)
    let _ctx = shared().lock().unwrap();
    let take = |seed: u64| -> Vec<f64> { PoissonSchedule::new(seed, 40.0).take(256).collect() };
    let a = take(7);
    let b = take(7);
    assert_eq!(a, b, "same seed, same run: identical schedule");
    pool::set_threads(1);
    let c = take(7);
    pool::set_threads(4);
    let d = take(7);
    pool::set_threads(pool::default_threads());
    assert_eq!(a, c, "thread count must not leak into the schedule");
    assert_eq!(a, d);
    assert_ne!(a, take(8), "different seed, different schedule");
    assert!(a.windows(2).all(|w| w[1] >= w[0]), "arrival times are monotone");
    assert!(a.iter().all(|t| t.is_finite() && *t >= 0.0));
}
