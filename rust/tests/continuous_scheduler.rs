//! Continuous-scheduler correctness: per-request token streams under
//! in-flight admission must be **bitwise identical** to the
//! batch-synchronous `serve_batch` reference — whatever the admission
//! policy, lane count, thread count, residency (dense, paged, legacy;
//! prefix-hit or cold) or compaction setting — and a recycled lane must
//! never expose its previous occupant's KV rows.

use std::sync::mpsc::channel;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use heapr::coordinator::{
    serve_continuous, AdmissionPolicy, Batcher, Request, Residency, SchedulerOpts, Server,
    StreamEvent,
};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::util::pool;

const DIR: &str = "artifacts/tiny";

struct Shared {
    engine: Engine,
    params: ParamStore,
}

// SAFETY: access is serialized through the Mutex (see integration.rs).
unsafe impl Send for Shared {}

fn shared() -> &'static Mutex<Shared> {
    static CTX: OnceLock<Mutex<Shared>> = OnceLock::new();
    CTX.get_or_init(|| {
        let engine = Engine::open(DIR).expect("open tiny preset");
        let params = ParamStore::init(&engine.manifest, 11);
        Mutex::new(Shared { engine, params })
    })
}

fn base_prompt() -> Vec<i32> {
    let g = Grammar::standard();
    let docs = g.corpus("wiki", 3, 4000);
    Split::from_docs(&docs, 64).chunks[0].clone()
}

/// A mixed-extent request stream: staggered prompt lengths and budgets
/// so lanes free at different steps and admission happens mid-decode.
fn mixed_requests() -> Vec<Request> {
    let base = base_prompt();
    (0..6u64)
        .map(|i| {
            let plen = 8 + 8 * (i as usize % 3); // 8 / 16 / 24
            let budget = 2 + (i as usize % 4) * 2; // 2 / 4 / 6 / 8
            Request::new(i, base[..plen].to_vec(), budget)
        })
        .collect()
}

fn queue(reqs: &[Request], policy: AdmissionPolicy) -> Batcher {
    let (tx, rx) = channel();
    for r in reqs {
        tx.send(r.clone()).unwrap();
    }
    drop(tx);
    Batcher::new(rx, vec![1, 4], Duration::from_millis(1)).admission(policy)
}

/// Reference: each request served alone through `serve_batch` (solo and
/// batched serving are already proven identical by the
/// serving_equivalence suite). Keyed by request id.
fn solo_reference(ctx: &Shared, reqs: &[Request]) -> Vec<(u64, Vec<i32>)> {
    pool::set_threads(1);
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let out = reqs
        .iter()
        .map(|r| {
            let resp = server.serve_batch(std::slice::from_ref(r)).unwrap();
            (r.id, resp.into_iter().next().unwrap().tokens)
        })
        .collect();
    pool::set_threads(pool::default_threads());
    out
}

fn tokens_by_id(mut resp: Vec<heapr::coordinator::Response>) -> Vec<(u64, Vec<i32>)> {
    resp.sort_by_key(|r| r.id);
    resp.into_iter().map(|r| (r.id, r.tokens)).collect()
}

#[test]
fn continuous_matches_serve_batch_across_threads_and_residency() {
    let ctx = shared().lock().unwrap();
    let reqs = mixed_requests();
    let want = solo_reference(&ctx, &reqs);

    for threads in [1usize, 4] {
        pool::set_threads(threads);
        for residency in [Residency::Resident, Residency::Paged, Residency::Legacy] {
            let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
            server.set_residency(residency);
            let mut batcher = queue(&reqs, AdmissionPolicy::Fifo);
            let got = serve_continuous(&mut server, &mut batcher, SchedulerOpts::default())
                .unwrap();
            assert_eq!(got.len(), reqs.len(), "every request must complete");
            assert_eq!(
                tokens_by_id(got),
                want,
                "continuous tokens diverged ({residency:?}, {threads} threads)"
            );
            if residency != Residency::Legacy {
                assert_eq!(
                    server.metrics.decode_kv_upload_bytes, 0,
                    "continuous {residency:?} decode must never re-upload a KV cache"
                );
            }
            assert_eq!(server.metrics.requests, reqs.len());
            assert!(server.metrics.latencies_ms.iter().all(|&l| l >= 0.0));
        }
    }
    pool::set_threads(pool::default_threads());
}

#[test]
fn admission_order_lanes_and_compaction_do_not_change_tokens() {
    let ctx = shared().lock().unwrap();
    let reqs = mixed_requests();
    let want = solo_reference(&ctx, &reqs);

    for policy in [AdmissionPolicy::Fifo, AdmissionPolicy::GroupExtent] {
        for lanes in [Some(1), Some(4), None] {
            for compact in [true, false] {
                let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
                let mut batcher = queue(&reqs, policy);
                let opts = SchedulerOpts { lanes, compact, ..SchedulerOpts::default() };
                let got = serve_continuous(&mut server, &mut batcher, opts).unwrap();
                assert_eq!(
                    tokens_by_id(got),
                    want,
                    "tokens diverged (policy {policy:?}, lanes {lanes:?}, \
                     compact {compact})"
                );
            }
        }
    }
}

#[test]
fn streaming_events_reassemble_every_response() {
    let ctx = shared().lock().unwrap();
    let reqs = mixed_requests();
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let mut batcher = queue(&reqs, AdmissionPolicy::Fifo);
    let (tx, rx) = channel::<StreamEvent>();
    let opts = SchedulerOpts { stream: Some(tx), ..SchedulerOpts::default() };
    let responses = serve_continuous(&mut server, &mut batcher, opts).unwrap();
    let events: Vec<StreamEvent> = rx.into_iter().collect();

    for resp in &responses {
        let mine: Vec<&StreamEvent> =
            events.iter().filter(|e| e.id == resp.id).collect();
        assert_eq!(mine.len(), resp.tokens.len(), "req {}", resp.id);
        for (i, ev) in mine.iter().enumerate() {
            // events land in index order, tokens match the response, and
            // `done` fires exactly on the final token
            assert_eq!(ev.index, i, "req {}", resp.id);
            assert_eq!(ev.token, resp.tokens[i], "req {}", resp.id);
            assert_eq!(ev.done, i + 1 == resp.tokens.len(), "req {}", resp.id);
        }
    }
    let total: usize = responses.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(events.len(), total, "no stray events");
}

#[test]
fn recycled_lane_never_observes_previous_occupants_kv() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let base = base_prompt();

    for residency in [Residency::Resident, Residency::Paged, Residency::Legacy] {
        let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
        server.set_residency(residency);
        let max_pos = cfg.seq_len.min(cfg.max_decode_len);
        let mut state = server.empty_state(4, max_pos).unwrap();

        // occupant A: a long prompt fills many rows of lane 1
        let (_l, a) = server
            .prefill_with_capacity(&[base[..32].to_vec()], state.capacity())
            .unwrap();
        state.admit_lane(1, &a, 32).unwrap();
        a.release();
        let (k, _v) = state.kv_cache(0).unwrap();
        let row = |t: &heapr::tensor::Tensor, lane: usize, pos: usize| -> Vec<f32> {
            let hd = cfg.d_head;
            let s = t.shape()[2];
            let start = ((lane * cfg.n_heads) * s + pos) * hd;
            t.data()[start..start + hd].to_vec()
        };
        assert!(
            row(&k, 1, 31).iter().any(|&x| x != 0.0),
            "occupant A's rows must actually be there ({residency:?})"
        );

        // retire A: the lane is zeroed immediately
        state.zero_lane(1).unwrap();
        let (k, v) = state.kv_cache(0).unwrap();
        for pos in 0..32 {
            assert!(
                row(&k, 1, pos).iter().all(|&x| x == 0.0)
                    && row(&v, 1, pos).iter().all(|&x| x == 0.0),
                "row {pos} survived retirement ({residency:?})"
            );
        }

        // occupant B: a short prompt re-seats the lane; rows beyond B's
        // prompt must be zero, not A's leftovers
        let (_l, b) = server
            .prefill_with_capacity(&[base[..8].to_vec()], state.capacity())
            .unwrap();
        state.admit_lane(1, &b, 8).unwrap();
        b.release();
        let (k, v) = state.kv_cache(0).unwrap();
        assert!(row(&k, 1, 7).iter().any(|&x| x != 0.0), "B's rows seated");
        for pos in 8..32 {
            assert!(
                row(&k, 1, pos).iter().all(|&x| x == 0.0)
                    && row(&v, 1, pos).iter().all(|&x| x == 0.0),
                "recycled lane leaked occupant A at row {pos} ({residency:?})"
            );
        }
        // neighbouring lane 0 was never touched by any of it
        assert!(row(&k, 0, 0).iter().all(|&x| x == 0.0));
        state.release();
    }
}

#[test]
fn shared_prefix_admission_skips_prefill_and_matches_cold_path() {
    // Four requests sharing a long prompt prefix (the shared-system-prompt
    // pattern): under paged residency with the prefix cache on, later
    // admissions must seat by mapping the donor's pages and replaying
    // only the prompt tail — provably skipping prefill rows — while
    // producing tokens bit-identical to the cold path.
    let ctx = shared().lock().unwrap();
    let base = base_prompt();
    // plen 40 / 48 alternating: with the default 16-position page every
    // prompt past the first shares two full pages (32 tokens) of prefix
    let reqs: Vec<Request> = (0..4u64)
        .map(|i| Request::new(i, base[..40 + 8 * (i as usize % 2)].to_vec(), 4))
        .collect();
    let want = solo_reference(&ctx, &reqs);

    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    server.set_residency(Residency::Paged);
    let mut batcher = queue(&reqs, AdmissionPolicy::Fifo);
    let got = serve_continuous(&mut server, &mut batcher, SchedulerOpts::default()).unwrap();
    assert_eq!(
        tokens_by_id(got),
        want,
        "prefix-hit admission changed tokens vs the cold path"
    );
    assert!(
        server.metrics.prefix_pages_reused > 0,
        "shared-prefix workload must map donor pages"
    );
    assert!(
        server.metrics.prefill_rows_skipped > 0,
        "shared-prefix workload must skip prefill rows"
    );
    assert!(
        server.metrics.prefix_hit_rate() > 0.0 && server.metrics.prefix_hit_rate() <= 1.0,
        "hit rate out of range: {}",
        server.metrics.prefix_hit_rate()
    );

    // HEAPR_NO_PREFIX_CACHE equivalent (opts knob; env stays untouched in
    // a threaded test): same queue, cold admissions only, same tokens
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    server.set_residency(Residency::Paged);
    let mut batcher = queue(&reqs, AdmissionPolicy::Fifo);
    let opts = SchedulerOpts { prefix_cache: false, ..SchedulerOpts::default() };
    let got = serve_continuous(&mut server, &mut batcher, opts).unwrap();
    assert_eq!(tokens_by_id(got), want, "cold paged path diverged");
    assert_eq!(server.metrics.prefix_pages_reused, 0);
    assert_eq!(server.metrics.prefill_rows_skipped, 0);
}

#[test]
fn continuous_reports_true_per_request_latency() {
    // batch-at-once gives every request in a batch the same latency; the
    // scheduler must report per-request submission->retirement times
    let ctx = shared().lock().unwrap();
    let reqs = mixed_requests();
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let mut batcher = queue(&reqs, AdmissionPolicy::Fifo);
    let responses =
        serve_continuous(&mut server, &mut batcher, SchedulerOpts::default()).unwrap();
    assert_eq!(responses.len(), reqs.len());
    assert!(responses.iter().all(|r| r.latency_ms > 0.0));
    // lossless: every id comes back exactly once
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..reqs.len() as u64).collect::<Vec<_>>());
}
