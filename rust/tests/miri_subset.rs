//! The unsafe-substrate subset the nightly Miri CI tier interprets
//! (`make miri` → `cargo +nightly miri test --test miri_subset`): the
//! thread-pool fan-out, the `RowsPtr` disjoint-slice substrate behind
//! every parallel writer, the cache-blocked GEMM on the global pool, and
//! the serving lane primitives. Miri catches what tests cannot — UB from
//! overlap, out-of-bounds, dangling `TaskCtx` pointers, or data races —
//! so the tests here favor small `cfg!(miri)` shapes over throughput.
//!
//! The file also runs as a fast ordinary integration test with larger
//! shapes, so the subset itself cannot rot between nightly runs.
//!
//! Miri notes: env vars are isolated (reads return `Err`), so the pool
//! width is always set explicitly here; tests that touch the global pool
//! serialize via `test_serial_lock` and restore a workerless 1-lane pool
//! so no pool thread outlives the test process.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use heapr::runtime::{write_lane_f32, zero_lane_f32, PagedKv};
use heapr::tensor::gemm::{self, Layout};
use heapr::tensor::Tensor;
use heapr::util::pool::{self, RowsPtr, ThreadPool};

/// Deterministic pseudo-random fill (no rand crate, Miri-stable).
fn fill(buf: &mut [f32], seed: u32) {
    let mut s = seed | 1;
    for v in buf.iter_mut() {
        // xorshift32; map to [-1, 1)
        s ^= s << 13;
        s ^= s >> 17;
        s ^= s << 5;
        *v = (s as f32 / u32::MAX as f32) * 2.0 - 1.0;
    }
}

#[test]
fn par_for_runs_every_index_exactly_once() {
    let n = if cfg!(miri) { 64 } else { 1000 };
    let p = ThreadPool::new(3);
    let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    p.par_for(n, |i| {
        hits[i].fetch_add(1, Ordering::Relaxed);
    });
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
}

#[test]
fn nested_par_for_caller_helps_without_deadlock() {
    let (outer, inner) = if cfg!(miri) { (3, 4) } else { (4, 64) };
    let p = std::sync::Arc::new(ThreadPool::new(2));
    let q = std::sync::Arc::clone(&p);
    let total = AtomicUsize::new(0);
    p.par_for(outer, |_| {
        q.par_for(inner, |_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
    });
    assert_eq!(total.load(Ordering::Relaxed), outer * inner);
}

#[test]
fn par_map_collects_in_index_order() {
    let n = if cfg!(miri) { 32 } else { 500 };
    let p = ThreadPool::new(2);
    let v = p.par_map(n, |i| i * 3 + 1);
    assert_eq!(v, (0..n).map(|i| i * 3 + 1).collect::<Vec<_>>());
}

#[test]
fn rows_ptr_disjoint_parallel_writes_land_intact() {
    let (rows, width) = if cfg!(miri) { (16, 8) } else { (128, 32) };
    let p = ThreadPool::new(4);
    let mut buf = vec![0.0f32; rows * width];
    // lint:allow(sendptr-confinement) this test exercises RowsPtr itself under Miri
    let ptr = RowsPtr::new(&mut buf);
    p.par_for(rows, |i| {
        // SAFETY: lane i writes only its own row i — disjoint ranges,
        // in bounds, and buf outlives the par_for.
        let row = unsafe { ptr.slice(i * width, width) };
        for (j, v) in row.iter_mut().enumerate() {
            *v = (i * width + j) as f32;
        }
    });
    for (k, &v) in buf.iter().enumerate() {
        assert_eq!(v, k as f32);
    }
}

/// The debug claim ledger must reject an overlapping claim *before* an
/// aliasing `&mut` exists — which is exactly why this test is UB-free
/// under Miri: the panic fires at the ledger check, not after two live
/// aliasing slices.
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "overlap")]
fn rows_ptr_overlap_claim_panics_before_aliasing() {
    let mut buf = vec![0.0f32; 32];
    // lint:allow(sendptr-confinement) this test exercises RowsPtr's claim ledger itself
    let ptr = RowsPtr::new(&mut buf);
    // SAFETY: in bounds; first claim of the generation.
    let _a = unsafe { ptr.slice(0, 20) };
    // SAFETY: in bounds; overlaps the first claim on purpose — must
    // panic at the ledger, before the aliasing slice is materialized.
    let _b = unsafe { ptr.slice(16, 8) };
}

#[test]
fn spawn_named_thread_runs_to_completion_with_name() {
    let h = pool::spawn_named("miri-probe", || {
        std::thread::current().name().map(String::from)
    });
    assert_eq!(h.join().unwrap().as_deref(), Some("heapr-miri-probe"));
}

/// Cache-blocked GEMM across the real global pool: the `RowsPtr` row
/// fan-out plus the `TaskCtx` borrow in `par_for`, end to end, and the
/// bitwise accumulation contract against the serial reference. Shapes
/// keep `m*n*k` above the parallel threshold so the unsafe path (not the
/// serial fallback) is what Miri interprets.
#[test]
fn parallel_blocked_gemm_is_bitwise_equal_to_reference() {
    let _guard = pool::test_serial_lock();
    pool::set_threads(2);
    let (m, k, n) = if cfg!(miri) { (32, 32, 32) } else { (96, 64, 48) };
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; n * k];
    fill(&mut a, 0xC0FFEE);
    fill(&mut b, 0xBEEF);
    let mut got = vec![0.0f32; m * n];
    let mut want = vec![0.0f32; m * n];
    gemm::blocked(Layout::TN, &a, &b, &mut got, m, k, n);
    gemm::reference(Layout::TN, &a, &b, &mut want, m, k, n);
    // bitwise, not approximate: the accumulation contract
    let eq = got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits());
    // back to a workerless pool before the assert can unwind the lock
    pool::set_threads(1);
    assert!(eq, "blocked GEMM diverged from reference");
}

#[test]
fn write_lane_zeroes_lane_then_copies_rect() {
    let mut dst = Tensor::from_vec(&[3, 2, 4], vec![7.0; 3 * 2 * 4]);
    // narrower source: copied columns land, the rest of the lane is zero
    let src = Tensor::from_vec(&[1, 2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    write_lane_f32(&mut dst, 1, &src).unwrap();
    let lane: &[f32] = &dst.data()[8..16];
    assert_eq!(lane, &[1.0, 2.0, 3.0, 0.0, 4.0, 5.0, 6.0, 0.0]);
    // neighboring lanes untouched
    assert!(dst.data()[..8].iter().all(|&v| v == 7.0));
    assert!(dst.data()[16..].iter().all(|&v| v == 7.0));

    zero_lane_f32(&mut dst, 1).unwrap();
    assert!(dst.data()[8..16].iter().all(|&v| v == 0.0));
    assert!(dst.data()[16..].iter().all(|&v| v == 7.0));

    // contract violations are errors, not UB
    assert!(write_lane_f32(&mut dst, 9, &src).is_err());
    assert!(zero_lane_f32(&mut dst, 3).is_err());
}

/// Property test for the paged KV allocator: a deterministic random walk
/// of write/share/append/retire operations across lanes, asserting the
/// pool invariants the serving path leans on — refcount consistency
/// (shared rows survive any one side's retirement, bit-identically),
/// rejection of writes into shared pages (append-only tails; no aliased
/// mutation), and zero leaked pages once every lane has drained.
#[test]
fn paged_kv_random_walk_holds_refcount_and_leak_invariants() {
    let (lanes, capacity, page, h, hd, steps) =
        if cfg!(miri) { (3, 8, 2, 1, 4, 60) } else { (6, 32, 4, 2, 8, 1200) };
    let mut pk = PagedKv::new(page, h, hd, None).unwrap();
    pk.alloc_resident("kc", lanes, capacity).unwrap();

    // host-side mirror of what each lane's rows should read back as
    let mut mirror: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; hd]; h * capacity]; lanes];
    // rows each lane owns (written or mapped); shared-from tracking is
    // implicit — the mirror holds the donor's values after share_prefix
    let mut rows_of: Vec<usize> = vec![0; lanes];

    let mut s: u32 = 0x5EED_1234;
    let mut rng = move || {
        s ^= s << 13;
        s ^= s >> 17;
        s ^= s << 5;
        s
    };
    for step in 0..steps {
        let lane = rng() as usize % lanes;
        match rng() % 4 {
            // write_lane: fresh rows replace the lane wholesale
            0 => {
                let rows = 1 + rng() as usize % capacity;
                let mut data = vec![0.0f32; h * rows * hd];
                fill(&mut data, step as u32 | 1);
                let src = Tensor::from_vec(&[1, h, rows, hd], data.clone());
                pk.write_lane("kc", lane, &src).unwrap();
                for hi in 0..h {
                    for si in 0..capacity {
                        mirror[lane][hi * capacity + si] = if si < rows {
                            data[(hi * rows + si) * hd..(hi * rows + si + 1) * hd].to_vec()
                        } else {
                            vec![0.0; hd]
                        };
                    }
                }
                rows_of[lane] = rows;
            }
            // share_prefix: map a donor's full pages into an empty lane
            1 => {
                let dst = rng() as usize % lanes;
                let npages = rows_of[lane] / page;
                if dst == lane || npages == 0 || pk.lane_pages("kc", dst).unwrap() > 0 {
                    continue;
                }
                let got = pk.share_prefix("kc", lane, dst, npages).unwrap();
                assert_eq!(got, npages, "share_prefix must map every requested page");
                for hi in 0..h {
                    for si in 0..npages * page {
                        mirror[dst][hi * capacity + si] = mirror[lane][hi * capacity + si].clone();
                    }
                }
                rows_of[dst] = npages * page;
            }
            // append_row: extend the lane's tail one position
            2 => {
                let si = rows_of[lane];
                if si >= capacity {
                    continue;
                }
                let covering_shared = rows_of[lane] % page != 0
                    && pk.lane_pages("kc", lane).unwrap() > 0
                    && {
                        // a mid-page append lands in the last mapped page;
                        // if that page is shared, the pool must refuse
                        let mut row = vec![0.0f32; hd];
                        fill(&mut row, 0xA11CE);
                        let r = pk.append_row("kc", lane, 0, si, &row);
                        if r.is_err() {
                            true
                        } else {
                            for hi in 1..h {
                                pk.append_row("kc", lane, hi, si, &row).unwrap();
                            }
                            for hi in 0..h {
                                mirror[lane][hi * capacity + si] = row.clone();
                            }
                            rows_of[lane] = si + 1;
                            false
                        }
                    };
                if !covering_shared && rows_of[lane] % page == 0 {
                    // page-aligned append: always lands on a fresh page
                    let mut row = vec![0.0f32; hd];
                    fill(&mut row, step as u32 ^ 0xF00D);
                    for hi in 0..h {
                        pk.append_row("kc", lane, hi, si, &row).unwrap();
                        mirror[lane][hi * capacity + si] = row.clone();
                    }
                    rows_of[lane] = si + 1;
                }
            }
            // zero_lane: retire; refcounted pages must not corrupt donors
            _ => {
                pk.zero_lane("kc", lane).unwrap();
                for cell in mirror[lane].iter_mut() {
                    cell.fill(0.0);
                }
                rows_of[lane] = 0;
            }
        }
        // full readback against the mirror every few steps (every step
        // under Miri would be quadratic in interpreter time)
        if step % 16 == 0 {
            for l in 0..lanes {
                for hi in 0..h {
                    for si in 0..capacity {
                        let got = pk.row("kc", l, hi, si).unwrap();
                        assert_eq!(
                            got,
                            &mirror[l][hi * capacity + si][..],
                            "lane {l} head {hi} row {si} diverged at step {step}"
                        );
                    }
                }
            }
        }
    }

    // drain: every lane retires, every page must come home
    for lane in 0..lanes {
        pk.zero_lane("kc", lane).unwrap();
    }
    assert_eq!(pk.live_pages(), 0, "pages leaked after drain");
    assert_eq!(pk.resident_bytes(), 0);
}

#[test]
fn pool_panic_is_contained_and_propagated() {
    let n = if cfg!(miri) { 16 } else { 200 };
    let p = ThreadPool::new(3);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        p.par_for(n, |i| {
            if i == n / 2 {
                panic!("expected probe panic");
            }
        });
    }));
    assert!(r.is_err(), "panic in par_for body must reach the caller");
    // the pool stays usable afterwards
    let count = Mutex::new(0usize);
    p.par_for(n, |_| {
        *count.lock().unwrap() += 1;
    });
    assert_eq!(*count.lock().unwrap(), n);
}
