//! Artifact-backed integration tests over the tiny preset.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).
//! One shared Engine per process: PJRT CPU client construction is cheap
//! but compilations are cached per Engine, so tests share a context.

use std::sync::{Mutex, OnceLock};

use heapr::config::RunConfig;
use heapr::data::corpus::Grammar;
use heapr::data::sampler::{CalibSampler, Split};
use heapr::eval::{ones_mask, perplexity};
use heapr::heapr::{heapr_scores, importance_scores, Calibrator, PrunePlan, Scope};
use heapr::model::store::ParamStore;
use heapr::runtime::{Engine, Value};
use heapr::tensor::Tensor;
use heapr::train::Trainer;

const DIR: &str = "artifacts/tiny";

struct Shared {
    engine: Engine,
    params: ParamStore,
    train_split: Split,
    eval_split: Split,
}

// SAFETY: Engine holds raw PJRT pointers and is not Send by default; the
// shared context is only ever accessed under the Mutex below, so at most
// one thread touches the client at a time (the same discipline the serving
// coordinator uses).
unsafe impl Send for Shared {}

// Engine is not Sync; serialize access through a mutex on a leaked context.
fn shared() -> &'static Mutex<Shared> {
    static CTX: OnceLock<Mutex<Shared>> = OnceLock::new();
    CTX.get_or_init(|| {
        let engine = Engine::open(DIR).expect("run `make artifacts` first");
        let cfg = engine.config().clone();
        let grammar = Grammar::standard();
        let docs = grammar.corpus("wiki", 0, 400_000);
        let (train_split, eval_split) =
            Split::from_docs(&docs, cfg.seq_len).train_eval(0.1);
        // short training run so pruning has signal
        let mut params = ParamStore::init(&engine.manifest, 0);
        let run = RunConfig { train_steps: 60, lr: 4e-3, ..RunConfig::default() };
        let mut trainer = Trainer::new(&engine);
        trainer.train(&mut params, &train_split, &run).expect("train");
        Mutex::new(Shared { engine, params, train_split, eval_split })
    })
}

#[test]
fn training_reduces_loss() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    // fresh params, few steps on a fixed batch must reduce loss
    let mut params = ParamStore::init(&ctx.engine.manifest, 9);
    let mut trainer = Trainer::new(&ctx.engine);
    let chunk = ctx.train_split.sample(cfg.batch, 5);
    let (tokens, targets) = CalibSampler::pack(&chunk, cfg.batch, cfg.seq_len);
    let (first, _) = trainer.step(&mut params, &tokens, &targets, 3e-3).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = trainer.step(&mut params, &tokens, &targets, 3e-3).unwrap().0;
    }
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn trained_model_beats_uniform() {
    let ctx = shared().lock().unwrap();
    let mask = ones_mask(&ctx.engine);
    let ppl = perplexity(&ctx.engine, &ctx.params, &mask, &ctx.eval_split, 4).unwrap();
    // uniform over 260 symbols = 260 ppl; byte LMs on the grammar corpus
    // should be far below after even 60 steps
    assert!(ppl < 30.0, "ppl {ppl}");
    assert!(ppl > 1.0);
}

#[test]
fn calibration_counts_match_topk() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let calib = ctx.train_split.sample(cfg.batch * 2, 0);
    let mut cal = Calibrator::new(&cfg);
    let mut total_tokens = 0usize;
    for (tokens, targets) in CalibSampler::batches(&calib, cfg.batch, cfg.seq_len) {
        cal.accumulate_pass1(&ctx.engine, &ctx.params, &tokens, &targets).unwrap();
        cal.accumulate_pass2(&ctx.engine, &ctx.params, &tokens).unwrap();
        total_tokens += cfg.batch * cfg.seq_len;
    }
    let stats = cal.finish();
    // Σ_e counts per layer == tokens · top_k
    for l in 0..cfg.n_layers {
        let mut sum = 0.0;
        for e in 0..cfg.n_experts {
            sum += stats.counts.at(&[l, e]);
        }
        assert_eq!(sum as usize, total_tokens * cfg.top_k, "layer {l}");
    }
    assert!(stats.calib_ce > 0.0 && stats.calib_ce.is_finite());
    // Ḡ diagonal nonnegative
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            let g = stats.gbar_at(l, e);
            for i in 0..cfg.d_model {
                assert!(g.at(&[i, i]) >= -1e-6);
            }
        }
    }
}

#[test]
fn importance_scores_nonnegative_and_structured() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let calib = ctx.train_split.sample(cfg.batch * 2, 1);
    let (scores, stats) = heapr_scores(&ctx.engine, &ctx.params, &calib).unwrap();
    assert_eq!(scores.shape(), &[cfg.n_layers, cfg.n_experts, cfg.d_inter]);
    assert!(scores.data().iter().all(|&s| s >= 0.0 && s.is_finite()));
    assert!(scores.data().iter().any(|&s| s > 0.0), "all-zero scores");
    // recompute one entry by hand from the stats: s = ½ q hsq_mean
    let (l, e) = (0, 0);
    let wd = ctx.params.get("l0.wd").unwrap().index0(e);
    let g = stats.gbar_at(l, e);
    let out = ctx.engine
        .run("quadform", &[Value::F32(wd), Value::F32(g)])
        .unwrap();
    let q = out.into_iter().next().unwrap().f32().unwrap();
    let hsq = stats.hsq_at(l, e);
    for k in [0usize, cfg.d_inter / 2] {
        let want = 0.5 * q.data()[k] * hsq.data()[k];
        let got = scores.at(&[l, e, k]);
        assert!(
            (got - want).abs() <= 1e-6 * want.abs().max(1e-6),
            "k={k}: {got} vs {want}"
        );
    }
}

#[test]
fn mask_eval_matches_unmasked_with_all_ones() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let chunk = ctx.eval_split.sample(cfg.batch, 2);
    let (tokens, targets) = CalibSampler::pack(&chunk, cfg.batch, cfg.seq_len);
    let mask = ones_mask(&ctx.engine);

    let mut inputs = ctx.params.values();
    inputs.push(Value::F32(mask));
    inputs.push(Value::I32(tokens));
    inputs.push(Value::I32(targets));
    let out = ctx.engine.run("loss_masked", &inputs).unwrap();
    let nll = out[0].clone().f32().unwrap().item();
    let cnt = out[1].clone().f32().unwrap().item();
    assert!(nll > 0.0 && cnt > 0.0);
    assert_eq!(cnt as usize, cfg.batch * cfg.seq_len);
}

#[test]
fn heapr_pruning_hurts_less_than_antiheapr() {
    // Decisive behavioural test of eq. 13: removing the LOWEST-importance
    // 25% must hurt much less than removing the HIGHEST-importance 25%.
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let calib = ctx.train_split.sample(cfg.batch * 4, 3);
    let (scores, _) = heapr_scores(&ctx.engine, &ctx.params, &calib).unwrap();

    let plan = PrunePlan::from_scores(&scores, 0.25, Scope::Global);
    // invert scores to prune the most-important instead
    let inv = Tensor::from_vec(
        scores.shape(),
        scores.data().iter().map(|&s| -s).collect(),
    );
    let anti = PrunePlan::from_scores(&inv, 0.25, Scope::Global);

    let base =
        perplexity(&ctx.engine, &ctx.params, &ones_mask(&ctx.engine), &ctx.eval_split, 2)
            .unwrap();
    let good =
        perplexity(&ctx.engine, &ctx.params, &plan.mask(), &ctx.eval_split, 2).unwrap();
    let bad =
        perplexity(&ctx.engine, &ctx.params, &anti.mask(), &ctx.eval_split, 2).unwrap();
    assert!(good < bad, "heapr {good} should beat anti-heapr {bad}");
    assert!(
        good < base * 2.0,
        "25% heapr pruning should be mild: {base} -> {good}"
    );
}

#[test]
fn seq_nll_rows_are_independent() {
    // packing different rows must not leak across rows: row i's nll is the
    // same whether packed alone or with others
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let chunk = ctx.eval_split.sample(cfg.batch, 4);
    let (tokens, targets) = CalibSampler::pack(&chunk, cfg.batch, cfg.seq_len);
    let mask = ones_mask(&ctx.engine);

    let mut inputs = ctx.params.values();
    inputs.push(Value::F32(mask.clone()));
    inputs.push(Value::I32(tokens.clone()));
    inputs.push(Value::I32(targets.clone()));
    let out = ctx.engine.run("seq_nll", &inputs).unwrap();
    let all_rows = out[0].clone().f32().unwrap();

    // repack row 0 alone (others PAD)
    let (solo_t, solo_g) = CalibSampler::pack(&chunk[..1], cfg.batch, cfg.seq_len);
    let mut inputs = ctx.params.values();
    inputs.push(Value::F32(mask));
    inputs.push(Value::I32(solo_t));
    inputs.push(Value::I32(solo_g));
    let out = ctx.engine.run("seq_nll", &inputs).unwrap();
    let solo = out[0].clone().f32().unwrap();
    let (a, b) = (all_rows.data()[0], solo.data()[0]);
    assert!(
        (a - b).abs() < 1e-3 * a.abs().max(1.0),
        "row leakage: {a} vs {b}"
    );
}

#[test]
fn quadform_artifact_matches_host_math() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let (d, di) = (cfg.d_model, cfg.d_inter);
    let mut rng = heapr::util::rng::Pcg64::new(4);
    let wd = Tensor::from_vec(&[d, di], (0..d * di).map(|_| rng.normal()).collect());
    let a = Tensor::from_vec(&[d, d], (0..d * d).map(|_| rng.normal() * 0.1).collect());
    // G = A A^T (PSD)
    let g = heapr::tensor::matmul_tn(&a, &a);
    let out = ctx.engine
        .run("quadform", &[Value::F32(wd.clone()), Value::F32(g.clone())])
        .unwrap();
    let q = out.into_iter().next().unwrap().f32().unwrap();
    for k in 0..di {
        // host: q_k = w_k^T G w_k
        let mut want = 0.0f32;
        for i in 0..d {
            for j in 0..d {
                want += wd.at(&[i, k]) * g.at(&[i, j]) * wd.at(&[j, k]);
            }
        }
        let got = q.data()[k];
        assert!(
            (got - want).abs() < 1e-2 * want.abs().max(1e-3),
            "k={k}: {got} vs {want}"
        );
    }
}

#[test]
fn importance_reuses_stats_consistently() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let calib = ctx.train_split.sample(cfg.batch, 6);
    let mut cal = Calibrator::new(&cfg);
    for (tokens, targets) in CalibSampler::batches(&calib, cfg.batch, cfg.seq_len) {
        cal.accumulate_pass1(&ctx.engine, &ctx.params, &tokens, &targets).unwrap();
        cal.accumulate_pass2(&ctx.engine, &ctx.params, &tokens).unwrap();
    }
    let stats = cal.finish();
    let s1 = importance_scores(&ctx.engine, &ctx.params, &stats).unwrap();
    let s2 = importance_scores(&ctx.engine, &ctx.params, &stats).unwrap();
    assert_eq!(s1, s2, "importance must be deterministic");
}
