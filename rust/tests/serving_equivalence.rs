//! Coordinator correctness: the per-layer serving composition (rust routing
//! + width-bucketed expert executables) must reproduce the monolithic
//! `forward_masked` artifact, unpruned and pruned; pruned serving must
//! equal masked evaluation; and the engine-resident decode session must be
//! bitwise identical to the legacy re-upload path — across thread counts —
//! while moving zero KV-cache bytes per step.

use std::sync::{Mutex, OnceLock};

use heapr::coordinator::{Residency, Server};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::data::tokenizer::{ByteTokenizer, PAD};
use heapr::heapr::{PrunePlan, Scope};
use heapr::model::store::ParamStore;
use heapr::runtime::{Engine, Value};
use heapr::tensor::{ITensor, Tensor};
use heapr::util::pool;

const DIR: &str = "artifacts/tiny";

struct Shared {
    engine: Engine,
    params: ParamStore,
}

// SAFETY: access is serialized through the Mutex (see integration.rs).
unsafe impl Send for Shared {}

fn shared() -> &'static Mutex<Shared> {
    static CTX: OnceLock<Mutex<Shared>> = OnceLock::new();
    CTX.get_or_init(|| {
        let engine = Engine::open(DIR).expect("run `make artifacts` first");
        // random params suffice for numerics-equivalence tests
        let params = ParamStore::init(&engine.manifest, 11);
        Mutex::new(Shared { engine, params })
    })
}

/// Reference logits from the monolithic artifact for one full-length row.
fn reference_logits(
    ctx: &Shared,
    prompt: &[i32],
    mask: &Tensor,
) -> Vec<f32> {
    let cfg = ctx.engine.config().clone();
    let (b, t, v) = (cfg.batch, cfg.seq_len, cfg.vocab);
    let mut toks = vec![PAD; b * t];
    // forward_masked has no length mask: use a full-length row
    assert_eq!(prompt.len(), t);
    toks[..t].copy_from_slice(prompt);
    let mut inputs = ctx.params.values();
    inputs.push(Value::F32(mask.clone()));
    inputs.push(Value::I32(ITensor::from_vec(&[b, t], toks)));
    let out = ctx.engine.run("forward_masked", &inputs).unwrap();
    let logits = out.into_iter().next().unwrap().f32().unwrap();
    // last position of row 0
    logits.data()[(t - 1) * v..t * v].to_vec()
}

fn test_prompt(t: usize) -> Vec<i32> {
    let g = Grammar::standard();
    let docs = g.corpus("wiki", 3, 4000);
    let split = Split::from_docs(&docs, t);
    split.chunks[0].clone()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    let max = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max < tol, "{what}: max |Δlogit| = {max}");
}

#[test]
fn unpruned_prefill_matches_forward_masked() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let prompt = test_prompt(cfg.seq_len);
    let ones = Tensor::ones(&[cfg.n_layers, cfg.n_experts, cfg.d_inter]);
    let want = reference_logits(&ctx, &prompt, &ones);

    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let (logits, state) = server.prefill(&[prompt], 1).unwrap();
    state.release();
    assert_close(logits.data(), &want, 2e-3, "unpruned prefill");
}

#[test]
fn pruned_prefill_matches_masked_eval() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let prompt = test_prompt(cfg.seq_len);

    // random-ish but bucket-aligned plan from arbitrary scores
    let scores = Tensor::from_vec(
        &[cfg.n_layers, cfg.n_experts, cfg.d_inter],
        (0..cfg.n_layers * cfg.n_experts * cfg.d_inter)
            .map(|i| ((i * 2654435761) % 1000) as f32)
            .collect(),
    );
    let plan = PrunePlan::from_scores(&scores, 0.4, Scope::Global)
        .bucket_aligned(&scores, cfg.blk_i);
    let want = reference_logits(&ctx, &prompt, &plan.mask());

    let mut server = Server::new(&ctx.engine, &ctx.params, Some(&plan)).unwrap();
    let (logits, _state) = server.prefill(&[prompt], 1).unwrap();
    assert_close(logits.data(), &want, 2e-3, "pruned prefill vs masked eval");
}

#[test]
fn decode_extends_prefill_consistently() {
    // prefill(T tokens) + decode(token T) must equal prefill(T+1 tokens),
    // on both decode residencies
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let full = test_prompt(cfg.seq_len);
    let t_half = cfg.seq_len / 2;

    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    // reference: prefill over t_half+1 tokens, logits at last position
    let (want, _) = server.prefill(&[full[..t_half + 1].to_vec()], 1).unwrap();

    for residency in [Residency::Resident, Residency::Legacy] {
        server.set_residency(residency);
        // prefill t_half, then decode token at position t_half
        let (_l, mut state) = server.prefill(&[full[..t_half].to_vec()], 4).unwrap();
        let got = server
            .decode_step(&[full[t_half]], &[t_half], &mut state)
            .unwrap();
        assert_close(
            got.data(),
            want.data(),
            2e-3,
            &format!("decode vs prefill ({residency:?})"),
        );
    }
}

#[test]
fn resident_decode_is_bitwise_identical_to_legacy_across_threads() {
    let ctx = shared().lock().unwrap();
    let prompt = test_prompt(16);
    let mk = |id| heapr::coordinator::Request::new(id, prompt.clone(), 8);
    let reqs: Vec<_> = (0..3).map(mk).collect();

    // reference: legacy caches on the serial pool
    pool::set_threads(1);
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    server.set_residency(Residency::Legacy);
    let want: Vec<Vec<i32>> = server
        .serve_batch(&reqs)
        .unwrap()
        .into_iter()
        .map(|r| r.tokens)
        .collect();

    for threads in [1usize, 4, pool::default_threads()] {
        pool::set_threads(threads);
        for residency in [Residency::Resident, Residency::Legacy] {
            let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
            server.set_residency(residency);
            let got: Vec<Vec<i32>> = server
                .serve_batch(&reqs)
                .unwrap()
                .into_iter()
                .map(|r| r.tokens)
                .collect();
            assert_eq!(
                got, want,
                "tokens diverged ({residency:?}, {threads} threads)"
            );
        }
    }
    pool::set_threads(pool::default_threads());

    // logits too, stepwise and bitwise: run both residencies in lockstep
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    server.set_residency(Residency::Legacy);
    let (l0, mut s0) = server.prefill(&[prompt.clone()], 6).unwrap();
    server.set_residency(Residency::Resident);
    let (l1, mut s1) = server.prefill(&[prompt.clone()], 6).unwrap();
    assert_eq!(l0, l1, "prefill logits must match bitwise");
    let mut next = vec![l0.data()[0..ctx.engine.config().vocab]
        .iter()
        .enumerate()
        .max_by(|a, b| heapr::util::cmp::f32_nan_first(*a.1, *b.1))
        .unwrap()
        .0 as i32];
    let mut pos = prompt.len();
    for _ in 0..4 {
        // decode_step dispatches on the state's residency, not the
        // server's — the two states advance through the same server
        let a = server.decode_step(&next, &[pos], &mut s0).unwrap();
        let b = server.decode_step(&next, &[pos], &mut s1).unwrap();
        assert_eq!(a, b, "decode logits must match bitwise at pos {pos}");
        next = vec![a
            .data()
            .iter()
            .enumerate()
            .max_by(|x, y| heapr::util::cmp::f32_nan_first(*x.1, *y.1))
            .unwrap()
            .0 as i32];
        pos += 1;
    }
}

#[test]
fn resident_decode_uploads_zero_kv_bytes() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let prompt = test_prompt(16);
    let (h, hd, smax) = (cfg.n_heads, cfg.d_head, cfg.max_decode_len);

    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    server.set_residency(Residency::Resident);
    let (_l, mut state) = server.prefill(&[prompt.clone()], 4).unwrap();
    // resident caches are right-sized: prompt + max_new, not max_decode_len
    assert_eq!(state.capacity(), prompt.len() + 4);
    let (kc, _vc) = state.kv_cache(0).unwrap();
    assert_eq!(kc.shape(), &[1, h, prompt.len() + 4, hd]);

    let before = ctx.engine.upload_stats().1;
    server.decode_step(&[5], &[prompt.len()], &mut state).unwrap();
    let session_delta = ctx.engine.upload_stats().1 - before;
    assert_eq!(
        server.metrics.decode_kv_upload_bytes, 0,
        "session decode must never re-upload a KV cache"
    );
    state.release();

    server.set_residency(Residency::Legacy);
    let (_l, mut state) = server.prefill(&[prompt], 4).unwrap();
    assert_eq!(state.capacity(), smax);
    let before = ctx.engine.upload_stats().1;
    server.decode_step(&[5], &[16], &mut state).unwrap();
    let legacy_delta = ctx.engine.upload_stats().1 - before;
    // per-step KV traffic of the legacy path: K and V at full capacity,
    // every layer. The session step must (a) never touch it and (b) move
    // less than even one step's worth of it in total.
    let kv_bytes = (2 * cfg.n_layers * h * smax * hd * 4) as u64;
    assert_eq!(server.metrics.decode_kv_upload_bytes, kv_bytes);
    assert!(
        legacy_delta >= kv_bytes,
        "legacy step moved {legacy_delta} B < {kv_bytes} B of KV"
    );
    assert!(
        session_delta < kv_bytes,
        "session step moved {session_delta} B, more than the {kv_bytes} B \
         of KV traffic it is supposed to eliminate"
    );
}

#[test]
fn full_window_prompt_batched_with_short_request_serves() {
    // a prompt that fills the decode window is done after its first
    // token, but its stale position (== capacity) must not sink the
    // batch on the right-sized resident path — and must not perturb the
    // short request's generations
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let long = test_prompt(cfg.seq_len); // len == seq_len == max_pos
    let short = long[..8].to_vec();
    let mk = |id, p: &[i32], n| heapr::coordinator::Request::new(id, p.to_vec(), n);

    for residency in [Residency::Resident, Residency::Legacy] {
        let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
        server.set_residency(residency);
        let solo = server.serve_batch(&[mk(0, &short, 4)]).unwrap();
        let mixed = server
            .serve_batch(&[mk(1, &long, 2), mk(2, &short, 4)])
            .unwrap();
        assert_eq!(mixed.len(), 2, "{residency:?}");
        assert!(!mixed[0].tokens.is_empty());
        assert_eq!(
            mixed[1].tokens, solo[0].tokens,
            "short request diverged next to a full-window prompt ({residency:?})"
        );
    }
}

#[test]
fn prefill_capacity_is_clamped_to_prompt_and_window() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let max_pos = cfg.seq_len.min(cfg.max_decode_len);
    let prompt = test_prompt(16);
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    server.set_residency(Residency::Resident);
    // explicit capacity honored
    let (_, s) = server.prefill_with_capacity(&[prompt.clone()], 20).unwrap();
    assert_eq!(s.capacity(), 20);
    // never below the prompt (prefill rows must fit)
    let (_, s) = server.prefill_with_capacity(&[prompt.clone()], 4).unwrap();
    assert_eq!(s.capacity(), 16);
    // never above the decode window
    let (_, s) = server.prefill_with_capacity(&[prompt], 10_000).unwrap();
    assert_eq!(s.capacity(), max_pos);
}

#[test]
fn sessions_do_not_leak_state_between_requests() {
    // one server serving two different batches back to back must produce
    // the same generations as a fresh server per batch
    let ctx = shared().lock().unwrap();
    let long = test_prompt(16);
    let mk = |id, p: &[i32], n| heapr::coordinator::Request::new(id, p.to_vec(), n);

    let mut reused = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    reused.set_residency(Residency::Resident);
    let first: Vec<_> = (0..4).map(|i| mk(i, &long, 6)).collect();
    reused.serve_batch(&first).unwrap();
    let second: Vec<_> = (0..2).map(|i| mk(10 + i, &long[4..12], 5)).collect();
    let got = reused.serve_batch(&second).unwrap();

    let mut fresh = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    fresh.set_residency(Residency::Resident);
    let want = fresh.serve_batch(&second).unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.tokens, w.tokens, "req {} saw stale session state", g.id);
    }
}

#[test]
fn serve_batch_generates_deterministically() {
    let ctx = shared().lock().unwrap();
    let prompt = test_prompt(16);
    let mk = |id| heapr::coordinator::Request::new(id, prompt.clone(), 8);

    let mut s1 = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let r1 = s1.serve_batch(&[mk(0)]).unwrap();
    let mut s2 = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let r2 = s2.serve_batch(&[mk(1)]).unwrap();
    assert_eq!(r1[0].tokens, r2[0].tokens, "greedy decode must be deterministic");
    assert!(!r1[0].tokens.is_empty());
    assert!(s1.metrics.generated_tokens >= r1[0].tokens.len());
    let text = ByteTokenizer.decode(&r1[0].tokens);
    assert!(text.len() <= 8 * 4);
}

#[test]
fn batched_serving_matches_single() {
    // same prompt served solo and in a batch of 4 must generate the same
    // tokens (padding rows must not contaminate real rows)
    let ctx = shared().lock().unwrap();
    let prompt = test_prompt(16);
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let solo = server.serve_batch(&[heapr::coordinator::Request::new(0, prompt.clone(), 6)])
        .unwrap();
    let reqs: Vec<_> = (0..4)
        .map(|i| heapr::coordinator::Request::new(i, prompt.clone(), 6))
        .collect();
    let batch = server.serve_batch(&reqs).unwrap();
    for r in &batch {
        assert_eq!(r.tokens, solo[0].tokens, "req {}", r.id);
    }
}
