//! Coordinator correctness: the per-layer serving composition (rust routing
//! + width-bucketed expert executables) must reproduce the monolithic
//! `forward_masked` artifact, unpruned and pruned; and pruned serving must
//! equal masked evaluation.

use std::sync::{Mutex, OnceLock};

use heapr::coordinator::Server;
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::data::tokenizer::{ByteTokenizer, PAD};
use heapr::heapr::{heapr_scores, PrunePlan, Scope};
use heapr::model::store::ParamStore;
use heapr::runtime::{Engine, Value};
use heapr::tensor::{ITensor, Tensor};

const DIR: &str = "artifacts/tiny";

struct Shared {
    engine: Engine,
    params: ParamStore,
}

// SAFETY: access is serialized through the Mutex (see integration.rs).
unsafe impl Send for Shared {}

fn shared() -> &'static Mutex<Shared> {
    static CTX: OnceLock<Mutex<Shared>> = OnceLock::new();
    CTX.get_or_init(|| {
        let engine = Engine::open(DIR).expect("run `make artifacts` first");
        // random params suffice for numerics-equivalence tests
        let params = ParamStore::init(&engine.manifest, 11);
        Mutex::new(Shared { engine, params })
    })
}

/// Reference logits from the monolithic artifact for one full-length row.
fn reference_logits(
    ctx: &Shared,
    prompt: &[i32],
    mask: &Tensor,
) -> Vec<f32> {
    let cfg = ctx.engine.config().clone();
    let (b, t, v) = (cfg.batch, cfg.seq_len, cfg.vocab);
    let mut toks = vec![PAD; b * t];
    // forward_masked has no length mask: use a full-length row
    assert_eq!(prompt.len(), t);
    toks[..t].copy_from_slice(prompt);
    let mut inputs = ctx.params.values();
    inputs.push(Value::F32(mask.clone()));
    inputs.push(Value::I32(ITensor::from_vec(&[b, t], toks)));
    let out = ctx.engine.run("forward_masked", &inputs).unwrap();
    let logits = out.into_iter().next().unwrap().f32().unwrap();
    // last position of row 0
    logits.data()[(t - 1) * v..t * v].to_vec()
}

fn test_prompt(t: usize) -> Vec<i32> {
    let g = Grammar::standard();
    let docs = g.corpus("wiki", 3, 4000);
    let split = Split::from_docs(&docs, t);
    split.chunks[0].clone()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    let max = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max < tol, "{what}: max |Δlogit| = {max}");
}

#[test]
fn unpruned_prefill_matches_forward_masked() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let prompt = test_prompt(cfg.seq_len);
    let ones = Tensor::ones(&[cfg.n_layers, cfg.n_experts, cfg.d_inter]);
    let want = reference_logits(&ctx, &prompt, &ones);

    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let (logits, _caches) = server.prefill(&[prompt]).unwrap();
    assert_close(logits.data(), &want, 2e-3, "unpruned prefill");
}

#[test]
fn pruned_prefill_matches_masked_eval() {
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let prompt = test_prompt(cfg.seq_len);

    // random-ish but bucket-aligned plan from arbitrary scores
    let scores = Tensor::from_vec(
        &[cfg.n_layers, cfg.n_experts, cfg.d_inter],
        (0..cfg.n_layers * cfg.n_experts * cfg.d_inter)
            .map(|i| ((i * 2654435761) % 1000) as f32)
            .collect(),
    );
    let plan = PrunePlan::from_scores(&scores, 0.4, Scope::Global)
        .bucket_aligned(&scores, cfg.blk_i);
    let want = reference_logits(&ctx, &prompt, &plan.mask());

    let mut server = Server::new(&ctx.engine, &ctx.params, Some(&plan)).unwrap();
    let (logits, _caches) = server.prefill(&[prompt]).unwrap();
    assert_close(logits.data(), &want, 2e-3, "pruned prefill vs masked eval");
}

#[test]
fn decode_extends_prefill_consistently() {
    // prefill(T tokens) + decode(token T) must equal prefill(T+1 tokens)
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let full = test_prompt(cfg.seq_len);
    let t_half = cfg.seq_len / 2;

    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    // reference: prefill over t_half+1 tokens, logits at last position
    let (want, _) = server.prefill(&[full[..t_half + 1].to_vec()]).unwrap();

    // prefill t_half, then decode token at position t_half
    let (_l, mut caches) = server.prefill(&[full[..t_half].to_vec()]).unwrap();
    let got = server
        .decode_step(&[full[t_half]], &[t_half], &mut caches, 1)
        .unwrap();
    assert_close(got.data(), want.data(), 2e-3, "decode vs prefill");
}

#[test]
fn serve_batch_generates_deterministically() {
    let ctx = shared().lock().unwrap();
    let prompt = test_prompt(16);
    let mk = |id| heapr::coordinator::Request::new(id, prompt.clone(), 8);

    let mut s1 = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let r1 = s1.serve_batch(&[mk(0)]).unwrap();
    let mut s2 = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let r2 = s2.serve_batch(&[mk(1)]).unwrap();
    assert_eq!(r1[0].tokens, r2[0].tokens, "greedy decode must be deterministic");
    assert!(!r1[0].tokens.is_empty());
    assert!(s1.metrics.generated_tokens >= r1[0].tokens.len());
    let text = ByteTokenizer.decode(&r1[0].tokens);
    assert!(text.len() <= 8 * 4);
}

#[test]
fn batched_serving_matches_single() {
    // same prompt served solo and in a batch of 4 must generate the same
    // tokens (padding rows must not contaminate real rows)
    let ctx = shared().lock().unwrap();
    let prompt = test_prompt(16);
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    let solo = server.serve_batch(&[heapr::coordinator::Request::new(0, prompt.clone(), 6)])
        .unwrap();
    let reqs: Vec<_> = (0..4)
        .map(|i| heapr::coordinator::Request::new(i, prompt.clone(), 6))
        .collect();
    let batch = server.serve_batch(&reqs).unwrap();
    for r in &batch {
        assert_eq!(r.tokens, solo[0].tokens, "req {}", r.id);
    }
}
