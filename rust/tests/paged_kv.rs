//! Paged KV residency: bitwise parity with dense residents, refcounted
//! prefix-page lifetimes across lane retirement, and the acceptance
//! criterion that a fixed page budget admits strictly more mixed-extent
//! lanes than fixed-extent rectangles.

use std::sync::{Mutex, OnceLock};

use heapr::coordinator::{Request, Residency, Server};
use heapr::model::store::ParamStore;
use heapr::runtime::{Engine, PagedKv};
use heapr::tensor::Tensor;

const DIR: &str = "artifacts/tiny";

struct Shared {
    engine: Engine,
    params: ParamStore,
}

// SAFETY: access is serialized through the Mutex (see integration.rs).
unsafe impl Send for Shared {}

fn shared() -> &'static Mutex<Shared> {
    static CTX: OnceLock<Mutex<Shared>> = OnceLock::new();
    CTX.get_or_init(|| {
        let engine = Engine::open(DIR).expect("open tiny preset");
        let params = ParamStore::init(&engine.manifest, 23);
        Mutex::new(Shared { engine, params })
    })
}

fn prompts() -> Vec<Vec<i32>> {
    // deterministic mixed-length prompts over the byte vocab
    (0..3usize)
        .map(|i| (0..12 + 10 * i).map(|j| ((j * 7 + i * 31) % 250 + 2) as i32).collect())
        .collect()
}

#[test]
fn paged_serve_is_bitwise_equal_to_dense_residency() {
    let ctx = shared().lock().unwrap();
    let reqs: Vec<Request> = prompts()
        .into_iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p, 4 + i))
        .collect();

    let mut dense = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    dense.set_residency(Residency::Resident);
    let want = dense.serve_batch(&reqs).unwrap();

    let mut paged = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    paged.set_residency(Residency::Paged);
    let got = paged.serve_batch(&reqs).unwrap();

    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.id, g.id);
        assert_eq!(w.tokens, g.tokens, "req {} tokens diverged under paging", w.id);
    }
    assert_eq!(dense.metrics.kv_pages_allocated, 0, "dense states own no pages");
    assert!(paged.metrics.kv_pages_allocated > 0, "paged serve must allocate pages");
    assert!(paged.metrics.kv_pages_peak > 0);
    assert_eq!(
        paged.metrics.decode_kv_upload_bytes, 0,
        "paged decode must never re-upload a KV cache"
    );
}

#[test]
fn paged_prefill_and_decode_caches_match_dense_bitwise() {
    // Stronger than token equality: the downloaded cache tensors (the
    // paged ones gathered through page tables) must match the dense
    // rectangles bit for bit, after prefill and after decode appends.
    let ctx = shared().lock().unwrap();
    let ps = prompts();

    let mut dense = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    dense.set_residency(Residency::Resident);
    let mut paged = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    paged.set_residency(Residency::Paged);

    let (ld, mut sd) = dense.prefill_with_capacity(&ps, 48).unwrap();
    let (lp, mut sp) = paged.prefill_with_capacity(&ps, 48).unwrap();
    assert_eq!(sd.capacity(), sp.capacity());
    assert_eq!(ld.data(), lp.data(), "prefill logits diverged");
    for l in 0..sd.n_layers() {
        let (kd, vd) = sd.kv_cache(l).unwrap();
        let (kp, vp) = sp.kv_cache(l).unwrap();
        assert_eq!(kd.shape(), kp.shape());
        assert_eq!(kd.data(), kp.data(), "layer {l} K diverged after prefill");
        assert_eq!(vd.data(), vp.data(), "layer {l} V diverged after prefill");
    }

    // two decode steps: the paged append path must track the dense one
    let argmax = |logits: &Tensor, row: usize| -> i32 {
        let v = logits.shape()[1];
        let xs = &logits.data()[row * v..(row + 1) * v];
        let mut best = 0usize;
        for (j, &x) in xs.iter().enumerate() {
            if x > xs[best] {
                best = j;
            }
        }
        best as i32
    };
    let mut next: Vec<i32> = vec![5, 6, 7];
    let mut poss: Vec<usize> = ps.iter().map(|p| p.len()).collect();
    for _ in 0..2 {
        let od = dense.decode_step(&next, &poss, &mut sd).unwrap();
        let op = paged.decode_step(&next, &poss, &mut sp).unwrap();
        assert_eq!(od.data(), op.data(), "decode logits diverged");
        for (i, p) in poss.iter_mut().enumerate() {
            next[i] = argmax(&od, i);
            *p += 1;
        }
    }
    for l in 0..sd.n_layers() {
        let (kd, _) = sd.kv_cache(l).unwrap();
        let (kp, _) = sp.kv_cache(l).unwrap();
        assert_eq!(kd.data(), kp.data(), "layer {l} K diverged after decode");
    }
    sd.release();
    sp.release();
}

#[test]
fn retired_sharer_cannot_zero_live_prefix_pages() {
    // The zero_lane satellite at the serve layer: a donor lane retiring
    // must only drop its refcounts — a prefix page still mapped by a live
    // sharer keeps its rows until the sharer retires too.
    let ctx = shared().lock().unwrap();
    let cfg = ctx.engine.config().clone();
    let mut server = Server::new(&ctx.engine, &ctx.params, None).unwrap();
    server.set_residency(Residency::Paged);

    let mut state = server.empty_state(2, 64).unwrap();
    let page = state.kv_page().expect("paged state");
    let npages = 32 / page;
    assert!(npages >= 1, "test assumes HEAPR_KV_PAGE <= 32 (default 16)");

    let prompt: Vec<i32> = (0..32).map(|j| (j % 250 + 2) as i32).collect();
    let (_l, solo) = server.prefill_with_capacity(&[prompt], state.capacity()).unwrap();
    state.admit_lane(0, &solo, 32).unwrap();
    solo.release();

    let mapped = state.map_prefix(0, 1, npages).unwrap();
    assert_eq!(
        mapped,
        npages * 2 * cfg.n_layers,
        "every layer's K and V tables must map the shared pages"
    );

    let row = |t: &Tensor, lane: usize, pos: usize| -> Vec<f32> {
        let (h, hd, s) = (cfg.n_heads, cfg.d_head, t.shape()[2]);
        let start = ((lane * h) * s + pos) * hd;
        t.data()[start..start + hd].to_vec()
    };

    // donor retires: the sharer's view of the prefix must survive intact
    let (k_before, _) = state.kv_cache(0).unwrap();
    state.zero_lane(0).unwrap();
    let (k, v) = state.kv_cache(0).unwrap();
    for pos in 0..npages * page {
        assert_eq!(
            row(&k, 1, pos),
            row(&k_before, 1, pos),
            "retiring the donor corrupted the sharer's prefix row {pos}"
        );
    }
    assert!(row(&k, 1, 0).iter().any(|&x| x != 0.0), "shared rows must be real data");
    for pos in 0..32 {
        assert!(
            row(&k, 0, pos).iter().all(|&x| x == 0.0)
                && row(&v, 0, pos).iter().all(|&x| x == 0.0),
            "the donor's own lane view must be zeroed at row {pos}"
        );
    }

    // sharer retires: now — and only now — the pages actually free
    state.zero_lane(1).unwrap();
    let (live, _peak, _total) = state.page_stats().unwrap();
    assert_eq!(live, 0, "refcounts must drain to zero once both sides retire");
    let (k, _) = state.kv_cache(0).unwrap();
    assert!(k.data().iter().all(|&x| x == 0.0));
    state.release();
}

#[test]
fn fixed_page_budget_admits_strictly_more_mixed_extent_lanes() {
    // Acceptance criterion, demonstrated as an assertion: under the same
    // byte budget, paged residency seats strictly more concurrent
    // mixed-extent lanes than fixed-extent rectangles.
    let (page, h, hd, capacity) = (16usize, 2usize, 32usize, 64usize);
    let budget_pages = 8usize;
    let mut pk = PagedKv::new(page, h, hd, Some(budget_pages)).unwrap();
    let budget_bytes = budget_pages * pk.page_bytes();

    // a dense lane is a full [h, capacity, hd] rectangle, whatever the
    // occupant actually wrote
    let dense_lane_bytes = h * capacity * hd * 4;
    let dense_lanes = budget_bytes / dense_lane_bytes;
    assert_eq!(dense_lanes, 2, "fixture: the budget fits exactly 2 dense lanes");

    // paged lanes pay only for written rows: short-prompt occupants with
    // a large *potential* extent cost one page each
    pk.alloc_resident("kc", 16, capacity).unwrap();
    let rows = page / 2; // 8-row prompts, extent up to `capacity`
    let mut seated = 0usize;
    for lane in 0..16 {
        let src = Tensor::from_vec(&[1, h, rows, hd], vec![1.0; h * rows * hd]);
        match pk.write_lane("kc", lane, &src) {
            Ok(()) => seated += 1,
            Err(_) => break, // budget exhausted
        }
    }
    assert_eq!(seated, budget_pages, "one page per short lane until the budget caps");
    assert!(
        seated > dense_lanes,
        "paging must admit strictly more mixed-extent lanes ({seated} vs {dense_lanes})"
    );
    assert_eq!(pk.live_pages(), budget_pages, "failed admissions must not leak pages");

    // retiring one lane frees its page for the next admission
    pk.zero_lane("kc", 0).unwrap();
    let src = Tensor::from_vec(&[1, h, rows, hd], vec![2.0; h * rows * hd]);
    pk.write_lane("kc", 15, &src).unwrap();
    assert_eq!(pk.live_pages(), budget_pages);
}
