"""HEAPr calibration math, verified against brute force.

The decisive tests:
  * pass-1 tap gradients == direct autodiff w.r.t. expert outputs,
  * the q·h² factorisation == brute-force e_k^T Ḡ e_k,
  * the full HEAPr score pipeline == a from-scratch numpy recomputation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import calib as C
from compile import model as M
from compile.configs import get
from compile.kernels import ref

CFG = get("tiny")


@pytest.fixture(scope="module")
def setup(rng):
    params = M.init_params(CFG, seed=1)
    tokens = jnp.asarray(rng.integers(0, 256, size=(2, CFG.seq_len)), jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    return params, tokens, targets


def test_pass1_shapes_and_psd(setup):
    params, tokens, targets = setup
    ce, gsum, counts = C.calib_pass1(params, tokens, targets, CFG)
    L, E, d = CFG.n_layers, CFG.n_experts, CFG.d_model
    assert gsum.shape == (L, E, d, d)
    assert counts.shape == (L, E)
    g = np.asarray(gsum)
    # every accumulated covariance is symmetric PSD
    np.testing.assert_allclose(g, np.swapaxes(g, -1, -2), rtol=1e-4, atol=1e-6)
    for l in range(L):
        for e in range(E):
            ev = np.linalg.eigvalsh(g[l, e])
            assert ev.min() > -1e-4, (l, e, ev.min())
    # every token contributes top_k routings per layer
    B, T = tokens.shape
    np.testing.assert_allclose(np.asarray(counts).sum(axis=1),
                               B * T * CFG.top_k)


def test_pass1_gradients_match_direct_autodiff(setup):
    """Ḡ built from tap gradients must equal Ḡ built from explicit
    per-expert output gradients (chain rule: ∂ℓ/∂E_e = gate_e · ∂ℓ/∂y)."""
    params, tokens, targets = setup
    _, gsum, _ = C.calib_pass1(params, tokens, targets, CFG)
    mask = jnp.ones((CFG.n_layers, CFG.n_experts, CFG.d_inter), jnp.float32)
    B, T = tokens.shape

    # Brute force: perturb expert e's output in layer l additively.
    l, e = CFG.n_layers - 1, 1

    def loss_with_expert_tap(tap):
        x = params["embed"][tokens] + params["pos"][None, :T, :]
        for li in range(CFG.n_layers):
            prefix = f"l{li}."
            x = x + M.attention(M.rmsnorm(x, params[prefix + "ln1"]),
                                params, prefix, CFG)
            xn = M.rmsnorm(x, params[prefix + "ln2"])
            xf = xn.reshape(B * T, -1)
            gates, _ = M.router_gates(xf, params[prefix + "router"], CFG)
            y = jnp.zeros_like(xf)
            for ei in range(CFG.n_experts):
                h = M.atomic_activations(xf, params[prefix + "wg"][ei],
                                         params[prefix + "wu"][ei])
                out = h @ params[prefix + "wd"][ei].T
                if li == l and ei == e:
                    out = out + tap
                y = y + gates[:, ei:ei + 1] * out
            x = x + y.reshape(B, T, -1)
        x = M.rmsnorm(x, params["lnf"])
        logits = x @ params["embed"].T
        loss, _ = M.ce_loss(logits, targets)
        return loss

    tap0 = jnp.zeros((B * T, CFG.d_model), jnp.float32)
    g_direct = jax.grad(loss_with_expert_tap)(tap0)      # [N, d] = gate·∂ℓ/∂y...

    # NOTE: tap is added *before* the gate multiply is applied? No — it is
    # added to `out` and then multiplied by gate, so ∂ℓ/∂tap already includes
    # the gate factor — exactly g_{E_e} of eq. 15.
    G_direct = np.asarray(g_direct).T @ np.asarray(g_direct)
    np.testing.assert_allclose(np.asarray(gsum)[l, e], G_direct,
                               rtol=2e-3, atol=1e-6)


def test_pass2_shapes_and_counts(setup):
    params, tokens, _ = setup
    hsq, hmax, counts, probe = C.calib_pass2(params, tokens, CFG)
    assert jnp.isfinite(probe)
    L, E, di = CFG.n_layers, CFG.n_experts, CFG.d_inter
    assert hsq.shape == (L, E, di) and hmax.shape == (L, E, di)
    assert (np.asarray(hsq) >= 0).all()
    B, T = tokens.shape
    np.testing.assert_allclose(np.asarray(counts).sum(axis=1),
                               B * T * CFG.top_k)


def test_pass1_pass2_counts_agree(setup):
    params, tokens, targets = setup
    _, _, c1 = C.calib_pass1(params, tokens, targets, CFG)
    _, _, c2, _ = C.calib_pass2(params, tokens, CFG)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))


def test_importance_factorisation_vs_bruteforce(rng):
    """s̄_k = ½ q_k · mean(h_k²) must equal the paper's literal
    (1/|T|) Σ_x ½ e_k(x)^T Ḡ e_k(x)."""
    d, di, n = 16, 8, 24
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(di, d)) * 0.4, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(di, d)) * 0.4, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(d, di)) * 0.4, jnp.float32)
    a = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    G = a @ a.T

    h = np.asarray(ref.atomic_activations_ref(x, wg, wu))      # [n, di]
    q = np.asarray(ref.quadform_ref(wd, G))                    # [di]
    fact = 0.5 * q * (h ** 2).mean(axis=0)

    brute = np.zeros(di)
    wd_np, G_np = np.asarray(wd), np.asarray(G)
    for k in range(di):
        for t in range(n):
            e_k = h[t, k] * wd_np[:, k]
            brute[k] += 0.5 * e_k @ G_np @ e_k
    brute /= n
    np.testing.assert_allclose(fact, brute, rtol=1e-4)
