"""L2 model invariants: shapes, masking semantics, atomic decomposition,
pallas/jnp path equivalence, routing sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import get
from compile.kernels import ref

CFG = get("tiny")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def tokens(rng):
    return jnp.asarray(
        rng.integers(0, 256, size=(2, CFG.seq_len)), jnp.int32)


def ones_mask():
    return jnp.ones((CFG.n_layers, CFG.n_experts, CFG.d_inter), jnp.float32)


def test_forward_shapes(params, tokens):
    logits, gates, aux = M.forward(params, tokens, ones_mask(), CFG)
    B, T = tokens.shape
    assert logits.shape == (B, T, CFG.vocab)
    assert gates.shape == (CFG.n_layers, B * T, CFG.n_experts)
    assert np.isfinite(np.asarray(logits)).all()


def test_pallas_and_jnp_paths_agree(params, tokens):
    lp, _, _ = M.forward(params, tokens, ones_mask(), CFG, use_pallas=True)
    lj, _, _ = M.forward(params, tokens, ones_mask(), CFG, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lj),
                               rtol=1e-4, atol=1e-4)


def test_gates_topk_structure(params, tokens):
    _, gates, _ = M.forward(params, tokens, ones_mask(), CFG)
    g = np.asarray(gates)
    nonzero = (g > 0).sum(axis=-1)
    assert (nonzero == CFG.top_k).all()
    np.testing.assert_allclose(g.sum(axis=-1), 1.0, rtol=1e-5)


def test_expert_is_sum_of_atomic_experts(rng):
    """Eq. 6 of the paper: E(x) = Σ_j e^(j)(x)."""
    d, di, n = CFG.d_model, CFG.d_inter, 8
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(di, d)) * 0.3, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(di, d)) * 0.3, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(d, di)) * 0.3, jnp.float32)
    full = ref.expert_ffn_ref(x, wg, wu, wd)
    acc = jnp.zeros_like(full)
    for j in range(di):
        m = jnp.zeros(di, jnp.float32).at[j].set(1.0)
        acc = acc + ref.expert_ffn_ref(x, wg, wu, wd, m)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


def test_mask_zero_block_changes_output(params, tokens):
    mask = np.ones((CFG.n_layers, CFG.n_experts, CFG.d_inter), np.float32)
    mask[0, 0, :] = 0.0
    l0, _, _ = M.forward(params, tokens, ones_mask(), CFG)
    l1, _, _ = M.forward(params, tokens, jnp.asarray(mask), CFG)
    assert np.abs(np.asarray(l0) - np.asarray(l1)).max() > 0


def test_ce_loss_ignores_pad(params, tokens):
    logits, _, _ = M.forward(params, tokens, ones_mask(), CFG)
    tgt = np.asarray(tokens).copy()
    loss_all, cnt_all = M.ce_loss(logits, jnp.asarray(tgt))
    tgt_pad = tgt.copy()
    tgt_pad[:, -8:] = M.PAD
    loss_pad, cnt_pad = M.ce_loss(logits, jnp.asarray(tgt_pad))
    assert float(cnt_pad) == float(cnt_all) - 2 * 8
    assert np.isfinite(float(loss_pad))


def test_total_loss_grad_finite(params, tokens):
    mask = ones_mask()
    tgt = jnp.roll(tokens, -1, axis=1)

    def f(p):
        loss, _aux = M.total_loss(p, tokens, tgt, mask, CFG, use_pallas=False)
        return loss

    grads = jax.grad(f)(params)
    for k, g in grads.items():
        assert np.isfinite(np.asarray(g)).all(), k
