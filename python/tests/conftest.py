import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def randf(rng, *shape, scale=0.5):
    import jax.numpy as jnp
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
