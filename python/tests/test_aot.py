"""AOT exporter contract tests: manifest consistency, parameter-DCE guard,
HLO text properties the rust runtime depends on."""

import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(os.path.dirname(HERE), "artifacts", "tiny")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_has_core_artifacts(manifest):
    arts = manifest["artifacts"]
    for name in ["train_step", "forward_masked", "loss_masked", "seq_nll",
                 "calib_pass1", "calib_pass2", "quadform"]:
        assert name in arts, name


def test_param_registry_matches_model(manifest):
    from compile import model as M
    from compile.configs import get
    cfg = get("tiny")
    specs = M.param_specs(cfg)
    assert len(manifest["params"]) == len(specs)
    for got, (name, shape) in zip(manifest["params"], specs):
        assert got["name"] == name
        assert tuple(got["shape"]) == tuple(shape)


def test_hlo_parameter_counts_match_manifest(manifest):
    """The invariant the DCE guard enforces: for every artifact, the HLO
    ENTRY computation declares exactly the manifest's input count."""
    import re
    for name, art in manifest["artifacts"].items():
        path = os.path.join(ART, art["file"])
        with open(path) as f:
            text = f.read()
        entry = text[text.index("ENTRY "):]
        n = len(re.findall(r"= [a-z0-9\[\],{} ]+ parameter\(", entry))
        assert n == len(art["inputs"]), f"{name}: {n} vs {len(art['inputs'])}"


def test_train_step_output_arity(manifest):
    art = manifest["artifacts"]["train_step"]
    n_params = len(manifest["params"])
    # loss, ce, params', m', v'
    assert len(art["outputs"]) == 2 + 3 * n_params
    assert art["outputs"][0]["name"] == "loss"
    assert art["outputs"][1]["name"] == "ce"


def test_serving_buckets_covered(manifest):
    preset = manifest["preset"]
    arts = manifest["artifacts"]
    for b in preset["serve_batches"]:
        assert f"attn_prefill_b{b}" in arts
        assert f"attn_decode_b{b}" in arts
    for n in preset["token_buckets"]:
        assert f"moe_gate_n{n}" in arts
        assert f"lm_head_n{n}" in arts
        for w in preset["width_buckets"]:
            assert f"expert_n{n}_w{w}" in arts


def test_no_topk_largest_attribute(manifest):
    """xla_extension 0.5.1's HLO text parser rejects the `largest` attr
    jax.lax.top_k lowers to — model.py must keep using iterative argmax."""
    for name, art in manifest["artifacts"].items():
        with open(os.path.join(ART, art["file"])) as f:
            assert "largest=" not in f.read(), name
