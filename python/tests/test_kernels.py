"""Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes (token counts, model dims, tile sizes) and seeds;
every kernel must match its oracle to f32 accumulation tolerance. This is
the core L1 correctness signal the whole stack rests on.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.expert import expert_ffn, expert_ffn_sliced
from compile.kernels.gradcov import gradcov
from compile.kernels.hstats import hstats
from compile.kernels.quadform import quadform

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(rng, *shape, scale=0.5):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# hypothesis strategies: tile-aligned shape families
tiles_n = st.sampled_from([8, 16, 32])
tiles_i = st.sampled_from([8, 16])
mult = st.integers(min_value=1, max_value=4)
dims = st.sampled_from([16, 32, 64, 128])
seeds = st.integers(min_value=0, max_value=2 ** 31 - 1)


@settings(max_examples=25, deadline=None)
@given(blk_n=tiles_n, blk_i=tiles_i, mn=mult, mi=mult, d=dims, seed=seeds)
def test_expert_ffn_matches_ref(blk_n, blk_i, mn, mi, d, seed):
    rng = np.random.default_rng(seed)
    n, di = blk_n * mn, blk_i * mi
    x = _rand(rng, n, d)
    wg, wu = _rand(rng, di, d), _rand(rng, di, d)
    wd = _rand(rng, d, di)
    mask = jnp.asarray(rng.integers(0, 2, size=di), jnp.float32)
    got = expert_ffn(x, wg, wu, wd, mask, blk_n=blk_n, blk_i=blk_i)
    want = ref.expert_ffn_ref(x, wg, wu, wd, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=15, deadline=None)
@given(blk_n=tiles_n, blk_i=tiles_i, mn=mult, mi=mult, d=dims, seed=seeds)
def test_expert_ffn_sliced_matches_ref(blk_n, blk_i, mn, mi, d, seed):
    rng = np.random.default_rng(seed)
    n, w = blk_n * mn, blk_i * mi
    x = _rand(rng, n, d)
    wg, wu, wd = _rand(rng, w, d), _rand(rng, w, d), _rand(rng, d, w)
    got = expert_ffn_sliced(x, wg, wu, wd, blk_n=blk_n, blk_i=blk_i)
    want = ref.expert_ffn_ref(x, wg, wu, wd, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_mask_equals_slicing():
    """Masking atomic experts == physically slicing them (the invariant the
    whole eval-vs-serving split relies on)."""
    rng = np.random.default_rng(7)
    n, d, di = 32, 64, 32
    x = _rand(rng, n, d)
    wg, wu, wd = _rand(rng, di, d), _rand(rng, di, d), _rand(rng, d, di)
    keep = np.sort(rng.choice(di, size=16, replace=False))
    mask = np.zeros(di, np.float32)
    mask[keep] = 1.0
    masked = expert_ffn(x, wg, wu, wd, jnp.asarray(mask), blk_n=16, blk_i=8)
    sliced = expert_ffn_sliced(x, wg[keep], wu[keep], wd[:, keep],
                               blk_n=16, blk_i=8)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(sliced), **TOL)


@settings(max_examples=25, deadline=None)
@given(blk_n=tiles_n, mn=mult, d=dims, seed=seeds)
def test_gradcov_matches_ref(blk_n, mn, d, seed):
    rng = np.random.default_rng(seed)
    n = blk_n * mn
    g = _rand(rng, n, d)
    w = jnp.asarray(rng.random(n), jnp.float32)
    got = gradcov(g, w, blk_n=blk_n)
    want = ref.gradcov_ref(g, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_gradcov_zero_weights_drop_tokens():
    rng = np.random.default_rng(3)
    g = _rand(rng, 32, 16)
    w = np.zeros(32, np.float32)
    w[:8] = rng.random(8)
    got = gradcov(g, jnp.asarray(w), blk_n=8)
    want = ref.gradcov_ref(g[:8], jnp.asarray(w[:8]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=25, deadline=None)
@given(blk_i=tiles_i, mi=mult, d=dims, seed=seeds)
def test_quadform_matches_ref(blk_i, mi, d, seed):
    rng = np.random.default_rng(seed)
    di = blk_i * mi
    wd = _rand(rng, d, di)
    a = _rand(rng, d, d)
    G = a @ a.T  # PSD like a real covariance
    got = quadform(wd, G, blk_i=blk_i)
    want = ref.quadform_ref(wd, G)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_quadform_nonnegative_on_psd():
    rng = np.random.default_rng(11)
    wd = _rand(rng, 32, 16)
    a = _rand(rng, 32, 32)
    q = np.asarray(quadform(wd, a @ a.T, blk_i=8))
    assert (q >= -1e-5).all()


@settings(max_examples=25, deadline=None)
@given(blk_n=tiles_n, mn=mult, di=st.sampled_from([8, 16, 32, 64]), seed=seeds)
def test_hstats_matches_ref(blk_n, mn, di, seed):
    rng = np.random.default_rng(seed)
    n = blk_n * mn
    h = _rand(rng, n, di)
    m = jnp.asarray(rng.integers(0, 2, size=n), jnp.float32)
    sq, mx = hstats(h, m, blk_n=blk_n)
    wsq, wmx = ref.hstats_ref(h, m)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(wsq), **TOL)
    np.testing.assert_allclose(np.asarray(mx), np.asarray(wmx), **TOL)


def test_hstats_all_unrouted_is_zero():
    rng = np.random.default_rng(5)
    h = _rand(rng, 16, 8)
    sq, mx = hstats(h, jnp.zeros(16, jnp.float32), blk_n=8)
    assert np.asarray(sq).sum() == 0.0 and np.asarray(mx).sum() == 0.0
