"""train_step sanity: loss decreases on a fixed batch; Adam state updates."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import trainstep as T
from compile.configs import get

CFG = get("tiny")


def test_train_step_reduces_loss_on_fixed_batch(rng):
    params = M.init_params(CFG, seed=2)
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    m, v = dict(zeros), dict(zeros)
    tokens = jnp.asarray(rng.integers(0, 256, size=(2, CFG.seq_len)), jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)

    losses = []
    for step in range(8):
        loss, ce, params, m, v = T.train_step(
            params, m, v, jnp.asarray(step, jnp.int32),
            jnp.asarray(3e-3, jnp.float32), tokens, targets, CFG)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_adam_state_changes(rng):
    params = M.init_params(CFG, seed=3)
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    tokens = jnp.asarray(rng.integers(0, 256, size=(2, CFG.seq_len)), jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    _, _, p2, m2, v2 = T.train_step(
        params, dict(zeros), dict(zeros), jnp.asarray(0, jnp.int32),
        jnp.asarray(1e-3, jnp.float32), tokens, targets, CFG)
    assert any(np.abs(np.asarray(m2[k])).max() > 0 for k in m2)
    assert any(np.abs(np.asarray(v2[k])).max() > 0 for k in v2)
    # params moved
    moved = max(np.abs(np.asarray(p2[k] - params[k])).max() for k in params)
    assert moved > 0
