"""Router math: the iterative-argmax top-k (the lax.top_k substitute the
HLO-text parser forced on us) must match lax.top_k wherever ties don't
intervene, and the gate construction must satisfy top-k semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import get

CFG = get("tiny")


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 32), e=st.integers(2, 12),
       k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_topk_iterative_matches_lax(n, e, k, seed):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    # distinct values => no tie ambiguity
    base = rng.permutation(n * e).astype(np.float32).reshape(n, e)
    logits = jnp.asarray(base)
    v1, i1 = M.topk_iterative(logits, k)
    v2, i2 = jax.lax.top_k(logits, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_topk_tie_breaks_low_index():
    logits = jnp.asarray([[1.0, 1.0, 0.0]])
    _v, i = M.topk_iterative(logits, 2)
    assert list(np.asarray(i)[0]) == [0, 1]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_router_gates_semantics(seed):
    rng = np.random.default_rng(seed)
    xf = jnp.asarray(rng.normal(size=(16, CFG.d_model)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(CFG.n_experts, CFG.d_model)),
                         jnp.float32)
    gates, probs = M.router_gates(xf, router, CFG)
    g = np.asarray(gates)
    # exactly top_k nonzero per row, summing to 1
    assert ((g > 0).sum(axis=1) == CFG.top_k).all()
    np.testing.assert_allclose(g.sum(axis=1), 1.0, rtol=1e-5)
    # the nonzero experts are the argmax set of the logits
    logits = np.asarray(xf @ router.T)
    for t in range(16):
        top = set(np.argsort(-logits[t])[:CFG.top_k])
        assert set(np.nonzero(g[t])[0]) == top
    # probs are a full softmax
    np.testing.assert_allclose(np.asarray(probs).sum(axis=1), 1.0, rtol=1e-5)
