"""Serving sub-graphs must reproduce the monolithic forward.

The rust coordinator composes attn_prefill/attn_decode + moe_gate + sliced
experts + lm_head; these tests verify the composition *in python* equals
`model.forward`, so any rust-side mismatch is a rust bug, not a graph bug.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import serving as S
from compile.configs import get
from compile.kernels.expert import expert_ffn_sliced

CFG = get("tiny")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=4)


def compose_prefill(params, tokens):
    """Python mirror of the rust coordinator's prefill composition."""
    B, T = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :T, :]
    lmask = jnp.ones((B, T), jnp.float32)
    caches = []
    for l in range(CFG.n_layers):
        p = f"l{l}."
        x, k, v = S.attn_prefill(x, params[p + "ln1"], params[p + "wq"],
                                 params[p + "wk"], params[p + "wv"],
                                 params[p + "wo"], lmask, CFG)
        caches.append((k, v))
        xf = x.reshape(B * T, -1)
        xn, gates = S.moe_gate(xf, params[p + "ln2"], params[p + "router"], CFG)
        y = jnp.zeros_like(xf)
        for e in range(CFG.n_experts):
            out = expert_ffn_sliced(xn, params[p + "wg"][e],
                                    params[p + "wu"][e], params[p + "wd"][e],
                                    blk_n=CFG.blk_n, blk_i=CFG.blk_i)
            y = y + gates[:, e:e + 1] * out
        x = (xf + y).reshape(B, T, -1)
    logits = S.lm_head(x.reshape(B * T, -1), params["lnf"], params["embed"])
    return logits.reshape(B, T, -1), caches


def test_prefill_composition_matches_forward(params, rng):
    tokens = jnp.asarray(rng.integers(0, 256, size=(2, CFG.seq_len)), jnp.int32)
    mask = jnp.ones((CFG.n_layers, CFG.n_experts, CFG.d_inter), jnp.float32)
    want, _, _ = M.forward(params, tokens, mask, CFG)
    got, _ = compose_prefill(params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_next_token(params, rng):
    """Decoding token T given a T-token prefill cache must equal a (T+1)-token
    prefill — the KV-cache correctness invariant."""
    B, T = 2, 16
    tokens = np.asarray(rng.integers(0, 256, size=(B, T + 1)), np.int32)
    Smax = CFG.max_decode_len
    H, hd, d = CFG.n_heads, CFG.d_head, CFG.d_model

    # Full prefill over T+1 tokens = reference.
    full = jnp.asarray(tokens)
    x_full = params["embed"][full] + params["pos"][None, :T + 1, :]
    p = "l0."
    lmask = jnp.ones((B, T + 1), jnp.float32)
    y_ref, _, _ = S.attn_prefill(x_full, params[p + "ln1"], params[p + "wq"],
                                 params[p + "wk"], params[p + "wv"],
                                 params[p + "wo"], lmask, CFG)

    # Prefill T tokens, then decode token T.
    pre = jnp.asarray(tokens[:, :T])
    x_pre = params["embed"][pre] + params["pos"][None, :T, :]
    _, k, v = S.attn_prefill(x_pre, params[p + "ln1"], params[p + "wq"],
                             params[p + "wk"], params[p + "wv"],
                             params[p + "wo"], jnp.ones((B, T), jnp.float32),
                             CFG)
    kc = jnp.zeros((B, H, Smax, hd), jnp.float32).at[:, :, :T].set(k)
    vc = jnp.zeros((B, H, Smax, hd), jnp.float32).at[:, :, :T].set(v)
    x_new = (params["embed"][jnp.asarray(tokens[:, T:T + 1])]
             + params["pos"][None, T:T + 1, :])
    pos = jnp.full((B,), T, jnp.int32)
    y_dec, _, _ = S.attn_decode(x_new, params[p + "ln1"], params[p + "wq"],
                                params[p + "wk"], params[p + "wv"],
                                params[p + "wo"], kc, vc, pos, CFG)
    np.testing.assert_allclose(np.asarray(y_dec)[:, 0],
                               np.asarray(y_ref)[:, T],
                               rtol=2e-3, atol=2e-3)
