"""AOT exporter: lower every L2 graph to HLO text + write manifest.json.

Interchange is HLO *text*, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 rust crate links) rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Every graph is lowered with return_tuple=True; the rust runtime unwraps the
tuple. manifest.json records the preset, the flat parameter registry (the
layout contract with the rust ParamStore) and, for every artifact, the
ordered input/output names, shapes and dtypes.

Usage:  cd python && python -m compile.aot --preset small --out-dir ../artifacts/small
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import calib as C
from . import model as M
from . import serving as S
from . import trainstep as T
from .configs import get as get_preset

F32, I32 = jnp.float32, jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(d):
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(d).name]


class Exporter:
    def __init__(self, cfg, out_dir):
        self.cfg = cfg
        self.out_dir = out_dir
        self.manifest = {
            "preset": cfg.to_dict(),
            "params": [{"name": n, "shape": list(s)}
                       for n, s in M.param_specs(cfg)],
            "artifacts": {},
        }

    def export(self, name, fn, args):
        """args: list of (name, ShapeDtypeStruct). fn takes them positionally
        and returns a tuple of (name, array) pairs."""
        t0 = time.time()

        def positional(*xs):
            outs = fn(*xs)
            return tuple(v for _n, v in outs)

        arg_specs = [s for _n, s in args]
        lowered = jax.jit(positional).lower(*arg_specs)
        out_shapes = jax.eval_shape(positional, *arg_specs)
        out_names = fn.out_names  # set by the @named decorator
        assert len(out_names) == len(out_shapes), (name, out_names, out_shapes)

        text = to_hlo_text(lowered)
        # Guard against parameter DCE: the StableHLO->XlaComputation
        # conversion silently drops parameters that don't reach any output,
        # which would desynchronise the HLO from the manifest the rust
        # runtime marshals against. Fail the build loudly instead.
        import re
        entry = text[text.index("ENTRY "):]
        n_params = len(re.findall(r"= [a-z0-9\[\],{} ]+ parameter\(", entry))
        if n_params != len(args):
            raise SystemExit(
                f"{name}: HLO entry has {n_params} parameters but {len(args)} "
                f"inputs were declared — an input is unused (DCE'd). Make "
                f"every input reach an output (see calib_pass2's probe)."
            )
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": n, "shape": list(s.shape), "dtype": _dt(s.dtype)}
                       for n, s in args],
            "outputs": [{"name": n, "shape": list(s.shape), "dtype": _dt(s.dtype)}
                        for n, s in zip(out_names, out_shapes)],
        }
        print(f"  {name:<24s} {len(text):>9d} chars  {time.time()-t0:5.1f}s")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"manifest.json: {len(self.manifest['artifacts'])} artifacts")


def named(out_names):
    def deco(fn):
        fn.out_names = out_names
        return fn
    return deco


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small")
    ap.add_argument("--out-dir", default="../artifacts/small")
    ap.add_argument("--no-serving", action="store_true",
                    help="skip serving artifacts (faster CI builds)")
    a = ap.parse_args()
    cfg = get_preset(a.preset)
    os.makedirs(a.out_dir, exist_ok=True)
    ex = Exporter(cfg, a.out_dir)

    P = M.param_specs(cfg)
    names = [n for n, _ in P]
    B, Tn, V = cfg.batch, cfg.seq_len, cfg.vocab
    L, E, d, di = cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_inter

    pspecs = [(n, spec(s)) for n, s in P]

    def pdict(flat):
        return dict(zip(names, flat))

    nP = len(P)

    # ---- train_step -------------------------------------------------------
    @named(["loss", "ce"] + names + [f"m.{n}" for n in names]
           + [f"v.{n}" for n in names])
    def f_train(*xs):
        p = pdict(xs[:nP])
        m = pdict(xs[nP:2 * nP])
        v = pdict(xs[2 * nP:3 * nP])
        step, lr, tokens, targets = xs[3 * nP:]
        loss, ce, p2, m2, v2 = T.train_step(p, m, v, step, lr, tokens,
                                            targets, cfg)
        return ([("loss", loss), ("ce", ce)]
                + [(n, p2[n]) for n in names]
                + [(f"m.{n}", m2[n]) for n in names]
                + [(f"v.{n}", v2[n]) for n in names])

    train_args = (pspecs
                  + [(f"m.{n}", spec(s)) for n, s in P]
                  + [(f"v.{n}", spec(s)) for n, s in P]
                  + [("step", spec((), I32)), ("lr", spec(())),
                     ("tokens", spec((B, Tn), I32)),
                     ("targets", spec((B, Tn), I32))])
    ex.export("train_step", f_train, train_args)

    # ---- masked forward / loss (inference; pallas expert kernel) ----------
    @named(["logits"])
    def f_fwd(*xs):
        p = pdict(xs[:nP])
        mask, tokens = xs[nP:]
        logits, _g, _a = M.forward(p, tokens, mask, cfg, use_pallas=True)
        return [("logits", logits)]

    mask_spec = ("mask", spec((L, E, di)))
    ex.export("forward_masked", f_fwd,
              pspecs + [mask_spec, ("tokens", spec((B, Tn), I32))])

    @named(["nll_sum", "tok_cnt"])
    def f_loss(*xs):
        p = pdict(xs[:nP])
        mask, tokens, targets = xs[nP:]
        logits, _g, _a = M.forward(p, tokens, mask, cfg, use_pallas=True)
        mean, cnt = M.ce_loss(logits, targets)
        return [("nll_sum", mean * cnt), ("tok_cnt", cnt)]

    ex.export("loss_masked", f_loss,
              pspecs + [mask_spec, ("tokens", spec((B, Tn), I32)),
                        ("targets", spec((B, Tn), I32))])

    # Per-sequence NLL: one row per (task item, choice) — the zero-shot
    # evaluator packs B independent scored continuations per call.
    @named(["nll_rows", "cnt_rows"])
    def f_seqnll(*xs):
        p = pdict(xs[:nP])
        mask, tokens, targets = xs[nP:]
        logits, _g, _a = M.forward(p, tokens, mask, cfg, use_pallas=True)
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = jax.nn.one_hot(targets, cfg.vocab, dtype=jnp.float32)
        nll = -(logp * tgt).sum(axis=-1)                      # [B, T]
        w = (targets != M.PAD).astype(jnp.float32)
        return [("nll_rows", (nll * w).sum(axis=1)),
                ("cnt_rows", w.sum(axis=1))]

    ex.export("seq_nll", f_seqnll,
              pspecs + [mask_spec, ("tokens", spec((B, Tn), I32)),
                        ("targets", spec((B, Tn), I32))])

    # ---- HEAPr calibration (the paper's two passes) ------------------------
    @named(["ce", "gsum", "counts"])
    def f_c1(*xs):
        p = pdict(xs[:nP])
        tokens, targets = xs[nP:]
        ce, gsum, counts = C.calib_pass1(p, tokens, targets, cfg)
        return [("ce", ce), ("gsum", gsum), ("counts", counts)]

    ex.export("calib_pass1", f_c1,
              pspecs + [("tokens", spec((B, Tn), I32)),
                        ("targets", spec((B, Tn), I32))])

    @named(["hsq", "hmax", "counts", "probe"])
    def f_c2(*xs):
        p = pdict(xs[:nP])
        tokens = xs[nP]
        hsq, hmax, counts, probe = C.calib_pass2(p, tokens, cfg)
        return [("hsq", hsq), ("hmax", hmax), ("counts", counts),
                ("probe", probe)]

    ex.export("calib_pass2", f_c2, pspecs + [("tokens", spec((B, Tn), I32))])

    # ---- importance quadform (pallas) --------------------------------------
    @named(["q"])
    def f_quad(wd, G):
        from .kernels.quadform import quadform
        return [("q", quadform(wd, G, blk_i=cfg.blk_i))]

    ex.export("quadform", f_quad,
              [("wd", spec((d, di))), ("G", spec((d, d)))])

    if a.no_serving:
        ex.finish()
        return

    # ---- serving sub-graphs -------------------------------------------------
    H, hd, Smax = cfg.n_heads, cfg.d_head, cfg.max_decode_len
    attn_w = [("ln1", spec((d,))), ("wq", spec((d, d))), ("wk", spec((d, d))),
              ("wv", spec((d, d))), ("wo", spec((d, d)))]

    for b in cfg.serve_batches:
        @named(["y", "k", "v"])
        def f_prefill(x, ln1, wq, wk, wv, wo, lmask, _b=b):
            y, k, v = S.attn_prefill(x, ln1, wq, wk, wv, wo, lmask, cfg)
            return [("y", y), ("k", k), ("v", v)]

        ex.export(f"attn_prefill_b{b}", f_prefill,
                  [("x", spec((b, Tn, d)))] + attn_w
                  + [("len_mask", spec((b, Tn)))])

        @named(["y", "kcache", "vcache"])
        def f_decode(x, ln1, wq, wk, wv, wo, kc, vc, pos, _b=b):
            y, kc2, vc2 = S.attn_decode(x, ln1, wq, wk, wv, wo, kc, vc, pos, cfg)
            return [("y", y), ("kcache", kc2), ("vcache", vc2)]

        ex.export(f"attn_decode_b{b}", f_decode,
                  [("x", spec((b, 1, d)))] + attn_w
                  + [("kcache", spec((b, H, Smax, hd))),
                     ("vcache", spec((b, H, Smax, hd))),
                     ("pos", spec((b,), I32))])

    for n in cfg.token_buckets:
        @named(["xn", "gates"])
        def f_gate(x, ln2, router, _n=n):
            xn, gates = S.moe_gate(x, ln2, router, cfg)
            return [("xn", xn), ("gates", gates)]

        ex.export(f"moe_gate_n{n}", f_gate,
                  [("x", spec((n, d))), ("ln2", spec((d,))),
                   ("router", spec((E, d)))])

        @named(["logits"])
        def f_head(x, lnf, emb, _n=n):
            return [("logits", S.lm_head(x, lnf, emb))]

        ex.export(f"lm_head_n{n}", f_head,
                  [("x", spec((n, d))), ("lnf", spec((d,))),
                   ("embed", spec((V, d)))])

        for w in cfg.width_buckets:
            from .kernels.expert import expert_ffn_sliced

            @named(["ys"])
            def f_exp(xs, wg, wu, wd, _n=n, _w=w):
                return [("ys", expert_ffn_sliced(
                    xs, wg, wu, wd, blk_n=min(cfg.blk_n, _n), blk_i=cfg.blk_i))]

            ex.export(f"expert_n{n}_w{w}", f_exp,
                      [("xs", spec((n, d))), ("wg", spec((w, d))),
                       ("wu", spec((w, d))), ("wd", spec((d, w)))])

    ex.finish()


if __name__ == "__main__":
    main()
