"""Model presets shared by the L2 model, the AOT exporter, and (via
manifest.json) the rust coordinator.

Every field here is baked into the exported HLO artifacts — changing a
preset requires re-running `make artifacts`.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 260          # 256 bytes + PAD + BOS + EOS + SEP
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_experts: int = 8
    top_k: int = 2
    d_inter: int = 64         # atomic experts per expert
    seq_len: int = 128        # training / calibration sequence length
    batch: int = 8            # training / calibration / eval batch size
    blk_n: int = 32           # pallas token-tile
    blk_i: int = 16           # pallas atomic-block tile (width bucket unit)
    aux_coef: float = 0.01    # load-balancing loss coefficient
    # serving buckets
    serve_batches: tuple = (1, 8)
    token_buckets: tuple = (8, 32, 128)
    max_decode_len: int = 160

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def width_buckets(self) -> tuple:
        """Retained-width buckets for pruned expert executables."""
        return tuple(range(self.blk_i, self.d_inter + 1, self.blk_i))

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        d["width_buckets"] = list(self.width_buckets)
        d["serve_batches"] = list(self.serve_batches)
        d["token_buckets"] = list(self.token_buckets)
        return d


PRESETS = {
    # CI / rust integration tests: compiles in seconds.
    "tiny": ModelConfig(
        name="tiny", d_model=64, n_layers=2, n_heads=2, n_experts=4,
        top_k=2, d_inter=32, seq_len=64, batch=4, blk_n=16, blk_i=8,
        serve_batches=(1, 4), token_buckets=(8, 32), max_decode_len=96,
    ),
    # Default for experiments.
    "small": ModelConfig(
        name="small", d_model=128, n_layers=4, n_heads=4, n_experts=8,
        top_k=2, d_inter=64, seq_len=128, blk_n=32, blk_i=16,
    ),
    # Headline end-to-end run.
    "base": ModelConfig(
        name="base", d_model=192, n_layers=6, n_heads=6, n_experts=16,
        top_k=2, d_inter=96, seq_len=128, blk_n=32, blk_i=16,
    ),
}


def get(name: str) -> ModelConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise SystemExit(f"unknown preset {name!r}; choose from {sorted(PRESETS)}")
