"""L2: MiniMoE — the MoE transformer LM the paper's pipeline operates on.

Decoder-only transformer, RMSNorm pre-LN, causal attention, MoE FFN in every
layer (SiLU-gated experts — exactly the structure HEAPr decomposes), softmax
-after-top-k router, Switch-style load-balancing aux loss, tied LM head.

All functions are pure over an ordered param dict; `param_specs` fixes the
order that the AOT exporter and the rust checkpoint format share. The MoE
expert computation routes through the L1 Pallas kernel so it lowers into the
same HLO the rust runtime executes.

Training computes every expert densely and masks by the top-k gate values —
numerically identical to sparse dispatch (the masked gates are exact zeros),
while keeping all shapes static for AOT. The serving coordinator exploits
the sparsity for real (see aot.py serving artifacts).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.expert import expert_ffn

EPS = 1e-6
PAD = 256
BOS = 257
EOS = 258
SEP = 259


# --------------------------------------------------------------------------
# parameter registry
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the single source of truth for the
    flat parameter layout shared with rust via manifest.json."""
    d, di, e = cfg.d_model, cfg.d_inter, cfg.n_experts
    specs = [("embed", (cfg.vocab, d)), ("pos", (cfg.seq_len, d))]
    for l in range(cfg.n_layers):
        p = f"l{l}."
        specs += [
            (p + "ln1", (d,)),
            (p + "wq", (d, d)), (p + "wk", (d, d)),
            (p + "wv", (d, d)), (p + "wo", (d, d)),
            (p + "ln2", (d,)),
            (p + "router", (e, d)),
            (p + "wg", (e, di, d)), (p + "wu", (e, di, d)),
            (p + "wd", (e, d, di)),
        ]
    specs.append(("lnf", (d,)))
    return specs


def init_params(cfg: ModelConfig, seed: int = 0):
    """He-style init; rust re-implements the same scheme for its own runs
    (exact values need not match — training happens through train_step)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "lnf")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            scale = 0.02 if name in ("embed", "pos") else fan_in ** -0.5
            params[name] = (jax.random.normal(sub, shape, jnp.float32) * scale)
    return params


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def rmsnorm(x, w):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def attention(x, p, prefix, cfg: ModelConfig):
    """Causal MHA on [B, T, d] (returns the projected output, no residual)."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.d_head

    def split(w):
        return (x @ w.T).reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    q, k, v = split(p[prefix + "wq"]), split(p[prefix + "wk"]), split(p[prefix + "wv"])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, d)
    return out @ p[prefix + "wo"].T


def topk_iterative(logits, k):
    """Iterative-argmax top-k. Deliberately avoids jax.lax.top_k: its
    StableHLO->HLO conversion emits a TopK op with a `largest` attribute the
    xla_extension 0.5.1 text parser (what the rust runtime links) rejects.
    k is tiny (top-2 routing), so k argmax sweeps are cheap and lower to
    plain reduces. Ties resolve to the lowest index, deterministically."""
    vals, idxs = [], []
    x = logits
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)
        v = jnp.max(x, axis=-1)
        vals.append(v)
        idxs.append(i)
        x = x - jax.nn.one_hot(i, x.shape[-1], dtype=x.dtype) * 1e30
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def router_gates(xf, router, cfg: ModelConfig):
    """Dense top-k gates: [N, E] with softmax-over-top-k weights at the
    selected experts and exact zeros elsewhere; plus the full router
    softmax (for the aux loss)."""
    logits = xf @ router.T                                   # [N, E]
    vals, idx = topk_iterative(logits, cfg.top_k)
    weights = jax.nn.softmax(vals, axis=-1)                  # [N, k]
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)
    gates = jnp.einsum("nk,nke->ne", weights, onehot)
    probs = jax.nn.softmax(logits, axis=-1)
    return gates, probs


def moe_layer(x, p, prefix, mask_l, cfg: ModelConfig, use_pallas=True):
    """x: [B, T, d]; mask_l: [E, di] atomic-expert keep mask.
    Returns (y [B,T,d], gates [N,E], aux_loss scalar).

    use_pallas=False selects the jnp expert path: Pallas interpret kernels
    have no autodiff rule, so graphs that are differentiated (train_step,
    calib pass 1) use the numerically-identical reference computation.
    """
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    gates, probs = router_gates(xf, p[prefix + "router"], cfg)

    y = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        if use_pallas:
            out_e = expert_ffn(
                xf, p[prefix + "wg"][e], p[prefix + "wu"][e], p[prefix + "wd"][e],
                mask_l[e], blk_n=cfg.blk_n, blk_i=cfg.blk_i,
            )
        else:
            h = atomic_activations(xf, p[prefix + "wg"][e], p[prefix + "wu"][e])
            out_e = (h * mask_l[e][None, :]) @ p[prefix + "wd"][e].T
        y = y + gates[:, e:e + 1] * out_e

    # Switch-style load balancing: E · Σ_e f_e P_e  (f = routed fraction).
    f = (gates > 0).astype(jnp.float32).mean(axis=0)
    pbar = probs.mean(axis=0)
    aux = cfg.n_experts * jnp.sum(f * pbar)
    return y.reshape(B, T, d), gates, aux


def forward(params, tokens, mask, cfg: ModelConfig, moe_taps=None,
            use_pallas=True):
    """tokens: [B, T] i32; mask: [L, E, di] atomic keep-mask (ones = full).

    moe_taps: optional [L, B, T, d] zeros added to every MoE-layer output —
    gradients w.r.t. the taps are exactly ∂ℓ/∂y_moe_l (HEAPr pass 1).

    Returns (logits [B,T,V], gates [L,N,E], aux scalar).
    """
    B, T = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :T, :]
    gates_all = []
    aux_total = 0.0
    for l in range(cfg.n_layers):
        prefix = f"l{l}."
        x = x + attention(rmsnorm(x, params[prefix + "ln1"]), params, prefix, cfg)
        y, gates, aux = moe_layer(
            rmsnorm(x, params[prefix + "ln2"]), params, prefix, mask[l], cfg,
            use_pallas=use_pallas)
        if moe_taps is not None:
            y = y + moe_taps[l]
        x = x + y
        gates_all.append(gates)
        aux_total = aux_total + aux
    x = rmsnorm(x, params["lnf"])
    logits = x @ params["embed"].T
    return logits, jnp.stack(gates_all), aux_total / cfg.n_layers


def ce_loss(logits, targets):
    """Mean cross-entropy over non-PAD targets; also returns token count."""
    V = logits.shape[-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jax.nn.one_hot(targets, V, dtype=jnp.float32)
    nll = -(logp * tgt).sum(axis=-1)                          # [B, T]
    w = (targets != PAD).astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0), w.sum()


def total_loss(params, tokens, targets, mask, cfg: ModelConfig, moe_taps=None,
               use_pallas=True):
    logits, gates, aux = forward(params, tokens, mask, cfg, moe_taps,
                                 use_pallas=use_pallas)
    ce, _ = ce_loss(logits, targets)
    return ce + cfg.aux_coef * aux, (ce, gates)


def atomic_activations(x, wg, wu):
    """h_k(x) = SiLU(w_gate_k x)·(w_up_k x) — used by calib pass 2 (the
    Pallas hstats kernel consumes these)."""
    pre = x @ wg.T
    return pre * jax.nn.sigmoid(pre) * (x @ wu.T)
