"""L2: Adam train step, exported as one HLO artifact.

Rust owns the training *loop* (data order, logging, checkpoints); this graph
owns one optimisation step. Signature keeps params / Adam moments as flat
tensor lists in `param_specs` order so the rust ParamStore can marshal them
without pytree knowledge.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import model as M

B1, B2, ADAM_EPS = 0.9, 0.999, 1e-8


def train_step(params, m, v, step, lr, tokens, targets, cfg: ModelConfig):
    """One Adam step. step: i32 scalar (1-based after increment), lr: f32.

    Returns (loss, ce, params', m', v'). All dicts keyed like `params`.
    """
    mask = jnp.ones((cfg.n_layers, cfg.n_experts, cfg.d_inter), jnp.float32)

    def loss_fn(p):
        loss, (ce, _gates) = M.total_loss(p, tokens, targets, mask, cfg,
                                          use_pallas=False)
        return loss, ce

    (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - B1 ** t
    bc2 = 1.0 - B2 ** t

    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m_k = B1 * m[k] + (1.0 - B1) * g
        v_k = B2 * v[k] + (1.0 - B2) * g * g
        update = lr * (m_k / bc1) / (jnp.sqrt(v_k / bc2) + ADAM_EPS)
        new_p[k] = params[k] - update
        new_m[k] = m_k
        new_v[k] = v_k
    return loss, ce, new_p, new_m, new_v
