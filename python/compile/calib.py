"""L2: HEAPr calibration graphs — pass 1 (fwd+bwd) and pass 2 (fwd).

Pass 1 (eq. 15): per (layer, expert), the gradient covariance over routed
tokens,  Ḡ_{l,e} = Σ_t (g_{l,e,t})(g_{l,e,t})^T,  with
g_{l,e,t} = gate_{l,e}(x_t) · ∂ℓ/∂y_moe_l(x_t)  — the gate factor is the
chain rule through y = Σ_e gate_e·E_e(x); unrouted tokens have gate 0 and
drop out exactly. The per-layer ∂ℓ/∂y_moe is obtained by differentiating
w.r.t. zero-valued taps added to each MoE layer output (one backward pass
for all layers/experts at once, as the paper advertises).

Pass 2 (eq. 16 via the rank-1 factorisation, DESIGN.md §1): accumulate
hsq_{l,e,k} = Σ_{t routed} h_k(x_t)² and the CAMERA-P statistics. Rust
combines the passes: s̄_{l,e,k} = ½ · quadform(W_down, Ḡ/|T|)_k · hsq_k/|T|.

Everything returns *sums* plus counts so rust can stream batches and
normalise once at the end.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import model as M
from .kernels.gradcov import gradcov
from .kernels.hstats import hstats


def calib_pass1(params, tokens, targets, cfg: ModelConfig):
    """-> (loss, Gsum [L,E,d,d], counts [L,E])."""
    B, T = tokens.shape
    L, E, d = cfg.n_layers, cfg.n_experts, cfg.d_model
    mask = jnp.ones((L, E, cfg.d_inter), jnp.float32)
    taps = jnp.zeros((L, B, T, d), jnp.float32)

    def loss_fn(taps_):
        loss, (ce, gates) = M.total_loss(params, tokens, targets, mask, cfg,
                                         moe_taps=taps_, use_pallas=False)
        return loss, (ce, gates)

    grads, (ce, gates) = jax.grad(loss_fn, has_aux=True)(taps)
    g_flat = grads.reshape(L, B * T, d)                    # ∂ℓ/∂y_moe per layer

    gsum = []
    counts = []
    for l in range(L):
        row = []
        for e in range(E):
            w = gates[l][:, e]                             # gate value (0 if unrouted)
            row.append(gradcov(g_flat[l], w, blk_n=cfg.blk_n))
        gsum.append(jnp.stack(row))
        counts.append((gates[l] > 0).astype(jnp.float32).sum(axis=0))
    return ce, jnp.stack(gsum), jnp.stack(counts)


def calib_pass2(params, tokens, cfg: ModelConfig):
    """-> (hsq [L,E,di], hmax [L,E,di], counts [L,E], probe scalar).

    Forward-only; replays the trunk, taps each MoE layer's input and routing
    to accumulate routed atomic-activation statistics.

    `probe` is a throwaway scalar depending on the final normed stream: the
    StableHLO->XlaComputation conversion DCEs *parameters* whose value never
    reaches an output (here lnf and the last layer's W_down), which would
    desynchronise the HLO's parameter list from the manifest; the probe
    keeps every parameter live at zero extra cost.
    """
    B, T = tokens.shape
    L, E = cfg.n_layers, cfg.n_experts
    mask = jnp.ones((L, E, cfg.d_inter), jnp.float32)

    x = params["embed"][tokens] + params["pos"][None, :T, :]
    hsq_all, hmax_all, cnt_all = [], [], []
    for l in range(L):
        prefix = f"l{l}."
        x = x + M.attention(M.rmsnorm(x, params[prefix + "ln1"]), params, prefix, cfg)
        xn = M.rmsnorm(x, params[prefix + "ln2"])
        xf = xn.reshape(B * T, -1)
        gates, _ = M.router_gates(xf, params[prefix + "router"], cfg)

        y = jnp.zeros_like(xf)
        hsq_l, hmax_l = [], []
        for e in range(E):
            h = M.atomic_activations(xf, params[prefix + "wg"][e],
                                     params[prefix + "wu"][e])
            routed = (gates[:, e] > 0).astype(jnp.float32)
            sq, mx = hstats(h, routed, blk_n=cfg.blk_n)
            hsq_l.append(sq)
            hmax_l.append(mx)
            y = y + gates[:, e:e + 1] * (h @ params[prefix + "wd"][e].T)
        x = x + y.reshape(B, T, -1)
        hsq_all.append(jnp.stack(hsq_l))
        hmax_all.append(jnp.stack(hmax_l))
        cnt_all.append((gates > 0).astype(jnp.float32).sum(axis=0))
    probe = jnp.mean(M.rmsnorm(x, params["lnf"]))
    return jnp.stack(hsq_all), jnp.stack(hmax_all), jnp.stack(cnt_all), probe
