"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: python/tests/test_kernels.py sweeps
shapes/dtypes with hypothesis and asserts each Pallas kernel (interpret=True)
matches its oracle to float32 tolerance. The oracles are also what the L2
model *means*; the kernels are only allowed to be faster, never different.
"""

import jax.numpy as jnp
import jax


def silu(x):
    return x * jax.nn.sigmoid(x)


def expert_ffn_ref(x, wg, wu, wd, mask=None):
    """Gated-FFN expert: y = [SiLU(x Wg^T) * (x Wu^T) * mask] Wd^T.

    x: [N, d], wg/wu: [di, d], wd: [d, di], mask: [di] or None -> y [N, d].
    `mask` zeroes pruned atomic experts; equivalent to slicing them out.
    """
    h = silu(x @ wg.T) * (x @ wu.T)          # [N, di] atomic activations
    if mask is not None:
        h = h * mask[None, :]
    return h @ wd.T


def atomic_activations_ref(x, wg, wu):
    """h_k(x) = SiLU(w_gate_k x) * (w_up_k x) for all atomic experts k."""
    return silu(x @ wg.T) * (x @ wu.T)       # [N, di]


def gradcov_ref(g, w):
    """Weighted gradient covariance: G = sum_t (w_t g_t)(w_t g_t)^T.

    g: [N, d] per-token gradients, w: [N] weights (e.g. gate values for one
    expert; zero for unrouted tokens) -> [d, d].
    """
    a = g * w[:, None]
    return a.T @ a


def quadform_ref(wd, G):
    """q_k = w_down_k^T G w_down_k  (diag of Wd^T G Wd without forming it).

    wd: [d, di], G: [d, d] -> q [di].
    """
    return jnp.einsum("dk,de,ek->k", wd, G, wd)


def hstats_ref(h, m):
    """Routed activation statistics per atomic expert.

    h: [N, di] atomic activations, m: [N] 0/1 routed mask ->
      (sum_t m_t h_{t,k}^2, max_t m_t |h_{t,k}|)   both [di].
    """
    hm = h * m[:, None]
    return (hm * hm).sum(axis=0), jnp.abs(hm).max(axis=0)


def attention_ref(x, wq, wk, wv, wo, n_heads, len_mask=None):
    """Causal multi-head attention block (pre-LN residual handled by caller).

    x: [B, T, d]; wq/wk/wv/wo: [d, d]; len_mask: [B, T] 1=valid.
    Returns (y [B,T,d], K [B,H,T,hd], V [B,H,T,hd]).
    """
    B, T, d = x.shape
    hd = d // n_heads

    def split(w):
        return (x @ w.T).reshape(B, T, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(wq), split(wk), split(wv)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    if len_mask is not None:
        scores = jnp.where(len_mask[:, None, None, :] > 0, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, d)
    return out @ wo.T, k, v
