"""L1 Pallas kernel: routed atomic-activation statistics (HEAPr pass 2).

Per atomic expert k of a given expert, over the tokens routed to that expert:
  hsq_k  = Σ_t m_t · h_k(x_t)²       (HEAPr: mean_routed(h²) numerator)
  hmax_k = max_t m_t · |h_k(x_t)|    (CAMERA-P baseline: ‖Φ‖_∞ term)

One pass produces the sufficient statistics for both the paper's method and
its closest concurrent baseline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hstats_kernel(h_ref, m_ref, sq_ref, mx_ref):
    t = pl.program_id(0)
    hm = h_ref[...] * m_ref[...][:, None]          # [blk_n, di]
    sq = jnp.sum(hm * hm, axis=0)
    mx = jnp.max(jnp.abs(hm), axis=0)

    @pl.when(t == 0)
    def _init():
        sq_ref[...] = sq
        mx_ref[...] = mx

    @pl.when(t > 0)
    def _acc():
        sq_ref[...] += sq
        mx_ref[...] = jnp.maximum(mx_ref[...], mx)


@functools.partial(jax.jit, static_argnames=("blk_n",))
def hstats(h, m, *, blk_n=32):
    """h: [N, di] atomic activations, m: [N] 0/1 routed mask -> (hsq, hmax)."""
    n, di = h.shape
    assert n % blk_n == 0, (n, blk_n)
    return pl.pallas_call(
        _hstats_kernel,
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n, di), lambda t: (t, 0)),
            pl.BlockSpec((blk_n,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((di,), lambda t: (0,)),
            pl.BlockSpec((di,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((di,), jnp.float32),
            jax.ShapeDtypeStruct((di,), jnp.float32),
        ],
        interpret=True,
    )(h, m)
