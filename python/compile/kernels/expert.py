"""L1 Pallas kernel: fused gated-FFN expert, tiled over atomic-expert blocks.

This is the paper's compute hot-spot restructured for TPU (DESIGN.md
§Hardware-Adaptation): the `d_inter` axis — the axis HEAPr prunes — is tiled
into `blk_i`-wide blocks of atomic experts. One grid step loads the
(2·blk_i·d + d·blk_i) weights of a block into VMEM, forms the atomic
activations h = SiLU(x Wg^T) ⊙ (x Wu^T) on the VPU, and accumulates the
rank-blk_i update h @ Wd^T on the MXU. Pruning atomic experts shrinks the
retained width W, which shrinks the grid: the TPU speedup mechanism is
literally "fewer grid steps", mirroring the paper's FLOPs-reduction claim.

interpret=True is mandatory on this image: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expert_kernel(x_ref, wg_ref, wu_ref, wd_ref, mask_ref, o_ref):
    j = pl.program_id(1)
    x = x_ref[...]                       # [blk_n, d]
    wg = wg_ref[...]                     # [blk_i, d]
    wu = wu_ref[...]                     # [blk_i, d]
    wd = wd_ref[...]                     # [d, blk_i]
    m = mask_ref[...]                    # [blk_i]

    # Atomic activations for this block of atomic experts (VPU work).
    pre = jnp.dot(x, wg.T, preferred_element_type=jnp.float32)
    h = pre * jax.nn.sigmoid(pre) * jnp.dot(x, wu.T, preferred_element_type=jnp.float32)
    h = h * m[None, :]
    # Rank-blk_i update into the output tile (MXU work).
    y = jnp.dot(h, wd.T, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = y

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += y


@functools.partial(jax.jit, static_argnames=("blk_n", "blk_i"))
def expert_ffn(x, wg, wu, wd, mask, *, blk_n=32, blk_i=16):
    """y = [SiLU(x Wg^T) ⊙ (x Wu^T) ⊙ mask] Wd^T via Pallas.

    x: [N, d], wg/wu: [W, d], wd: [d, W], mask: [W] -> [N, d].
    N must divide by blk_n and W by blk_i (the AOT exporter guarantees both;
    the serving coordinator pads token batches to bucket sizes).
    """
    n, d = x.shape
    w = wg.shape[0]
    assert n % blk_n == 0 and w % blk_i == 0, (n, w, blk_n, blk_i)
    grid = (n // blk_n, w // blk_i)
    return pl.pallas_call(
        _expert_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_i, d), lambda i, j: (j, 0)),
            pl.BlockSpec((blk_i, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d, blk_i), lambda i, j: (0, j)),
            pl.BlockSpec((blk_i,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((blk_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(x, wg, wu, wd, mask)


def _expert_nomask_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    j = pl.program_id(1)
    x = x_ref[...]
    pre = jnp.dot(x, wg_ref[...].T, preferred_element_type=jnp.float32)
    h = pre * jax.nn.sigmoid(pre) * jnp.dot(x, wu_ref[...].T, preferred_element_type=jnp.float32)
    y = jnp.dot(h, wd_ref[...].T, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = y

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += y


@functools.partial(jax.jit, static_argnames=("blk_n", "blk_i"))
def expert_ffn_sliced(x, wg, wu, wd, *, blk_n=32, blk_i=16):
    """Mask-free variant for *physically pruned* experts (serving path).

    The retained width W = wg.shape[0] is already a width bucket; the grid
    over atomic blocks is W/blk_i steps — this is where pruning buys real
    latency at serve time.
    """
    n, d = x.shape
    w = wg.shape[0]
    assert n % blk_n == 0 and w % blk_i == 0, (n, w, blk_n, blk_i)
    grid = (n // blk_n, w // blk_i)
    return pl.pallas_call(
        _expert_nomask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_i, d), lambda i, j: (j, 0)),
            pl.BlockSpec((blk_i, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d, blk_i), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((blk_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(x, wg, wu, wd)
