"""L1 Pallas kernel: weighted gradient covariance Ḡ accumulation.

HEAPr pass 1 needs, per expert i,  Ḡ_i = Σ_{t routed to i} g_t g_t^T with
g_t = gate_i(x_t) · ∂ℓ/∂y_moe(x_t)  (eq. 15 of the paper; the gate factor is
the chain rule through y = Σ gate_i·E_i).

Rather than per-token d×d outer products (bandwidth-bound on any hardware),
we tile tokens and compute Ḡ += A_t^T A_t with A_t = diag(w) g — an
MXU-friendly GEMM reduction (DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gradcov_kernel(g_ref, w_ref, o_ref):
    t = pl.program_id(0)
    a = g_ref[...] * w_ref[...][:, None]        # [blk_n, d]
    cov = jnp.dot(a.T, a, preferred_element_type=jnp.float32)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = cov

    @pl.when(t > 0)
    def _acc():
        o_ref[...] += cov


@functools.partial(jax.jit, static_argnames=("blk_n",))
def gradcov(g, w, *, blk_n=32):
    """G = Σ_t (w_t g_t)(w_t g_t)^T.   g: [N, d], w: [N] -> [d, d]."""
    n, d = g.shape
    assert n % blk_n == 0, (n, blk_n)
    return pl.pallas_call(
        _gradcov_kernel,
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n, d), lambda t: (t, 0)),
            pl.BlockSpec((blk_n,), lambda t: (t,)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(g, w)
