"""L1 Pallas kernel: q = diag(W_down^T Ḡ W_down) — the HEAPr scoring hot-spot.

The paper's importance  s̄_k = ½·mean_routed(e_k^T Ḡ e_k)  factorises for
gated-FFN atomic experts as  s̄_k = ½·q_k·mean_routed(h_k²)  with
q_k = w_down_k^T Ḡ w_down_k (DESIGN.md §1). Computing q naively as
W_down^T (Ḡ W_down) materialises a d×di intermediate per expert; the kernel
tiles the di axis so only (d × blk_i) lives in VMEM besides Ḡ itself, and
never forms the di×di product.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quadform_kernel(wd_ref, g_ref, q_ref):
    wd = wd_ref[...]                               # [d, blk_i]
    gw = jnp.dot(g_ref[...], wd, preferred_element_type=jnp.float32)
    q_ref[...] = jnp.sum(wd * gw, axis=0)


@functools.partial(jax.jit, static_argnames=("blk_i",))
def quadform(wd, G, *, blk_i=16):
    """q_k = w_down_k^T G w_down_k.   wd: [d, di], G: [d, d] -> [di]."""
    d, di = wd.shape
    assert di % blk_i == 0, (di, blk_i)
    return pl.pallas_call(
        _quadform_kernel,
        grid=(di // blk_i,),
        in_specs=[
            pl.BlockSpec((d, blk_i), lambda j: (0, j)),
            pl.BlockSpec((d, d), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk_i,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((di,), jnp.float32),
        interpret=True,
    )(wd, G)
