"""L2: serving sub-graphs for the rust coordinator.

The coordinator composes per-layer pieces so it can do *sparse, width-
bucketed* expert dispatch — the mechanism that turns HEAPr's atomic pruning
into real latency wins:

  embed (rust lookup) → per layer: attn_prefill/attn_decode → moe_gate →
  [rust groups tokens per expert, pads to a token bucket, runs
   expert_n{N}_w{W} with that expert's sliced weights] → rust combines with
  gate weights + residual → … → lm_head.

Weights are runtime *inputs* everywhere, so one artifact serves every layer
and every pruned width: artifact count scales with bucket grids, not model
size.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import model as M


def attn_prefill(x, ln1, wq, wk, wv, wo, len_mask, cfg: ModelConfig):
    """x: [B,T,d] embedded tokens. Returns (x + attn(rms(x)), K, V) with
    K/V: [B,H,T,hd] for the decode cache. len_mask: [B,T] 1=valid."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.d_head
    xn = M.rmsnorm(x, ln1)

    def split(w):
        return (xn @ w.T).reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    q, k, v = split(wq), split(wk), split(wv)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    scores = jnp.where(len_mask[:, None, None, :] > 0, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    y = out.transpose(0, 2, 1, 3).reshape(B, T, d) @ wo.T
    return x + y, k, v


def attn_decode(x, ln1, wq, wk, wv, wo, kcache, vcache, pos, cfg: ModelConfig):
    """Single-token decode with KV cache.

    x: [B,1,d]; kcache/vcache: [B,H,S,hd]; pos: [B] i32 — the index this
    token writes to (= current length). Attends over cache[0..pos] inclusive
    of the new token. Returns (y [B,1,d], kcache', vcache')."""
    B, _, d = x.shape
    H, hd = cfg.n_heads, cfg.d_head
    S = kcache.shape[2]
    xn = M.rmsnorm(x, ln1)

    def split(w):
        return (xn @ w.T).reshape(B, H, hd)                  # T=1 squeezed

    q, k_new, v_new = split(wq), split(wk), split(wv)

    def upd(cache, new, p):
        # cache: [H,S,hd]; new: [H,hd]
        return jax.lax.dynamic_update_slice(cache, new[:, None, :], (0, p, 0))

    kcache = jax.vmap(upd)(kcache, k_new, pos)
    vcache = jax.vmap(upd)(vcache, v_new, pos)

    scores = jnp.einsum("bhd,bhsd->bhs", q, kcache) / jnp.sqrt(float(hd))
    valid = jnp.arange(S)[None, :] <= pos[:, None]           # [B,S]
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", attn, vcache).reshape(B, 1, d)
    return x + out @ wo.T, kcache, vcache


def moe_gate(x, ln2, router, cfg: ModelConfig):
    """x: [N,d] residual stream. Returns (rmsnorm'd tokens, dense top-k
    gates [N,E]) — the rust router consumes the gates to build per-expert
    token groups."""
    xn = M.rmsnorm(x, ln2)
    gates, _probs = M.router_gates(xn, router, cfg)
    return xn, gates


def lm_head(x, lnf, embed):
    """x: [N,d] -> logits [N,V] (tied head)."""
    return M.rmsnorm(x, lnf) @ embed.T
