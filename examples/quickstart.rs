//! Quickstart: the whole HEAPr pipeline on the tiny preset in ~a minute.
//!
//!   make artifacts && cargo run --release --offline --example quickstart
//!
//! Steps: open artifacts → build synthetic corpus → train a tiny MoE LM →
//! calibrate (2 fwd + 1 bwd) → score atomic experts → prune 25% globally →
//! compare perplexity → serve one pruned request.

use anyhow::Result;
use heapr::config::RunConfig;
use heapr::coordinator::{Request, Server};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::data::tokenizer::ByteTokenizer;
use heapr::eval::{ones_mask, perplexity};
use heapr::heapr::{heapr_scores, PrunePlan, Scope};
use heapr::model::flops::flops_reduction;
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::train::Trainer;

fn main() -> Result<()> {
    // 1. open the AOT artifacts (HLO text compiled once by `make artifacts`)
    let engine = Engine::open("artifacts/tiny")?;
    let cfg = engine.config().clone();
    println!("model: {} (d={}, L={}, E={}, d_inter={})",
             cfg.name, cfg.d_model, cfg.n_layers, cfg.n_experts, cfg.d_inter);

    // 2. synthetic topic-grammar corpus (stands in for WikiText-2)
    let grammar = Grammar::standard();
    let docs = grammar.corpus("wiki", 0, 400_000);
    let (train_split, eval_split) = Split::from_docs(&docs, cfg.seq_len).train_eval(0.1);

    // 3. train a small MoE LM entirely from rust via the train_step artifact
    let mut params = ParamStore::init(&engine.manifest, 0);
    let run = RunConfig { train_steps: 100, lr: 4e-3, ..Default::default() };
    let report = Trainer::new(&engine).train(&mut params, &train_split, &run)?;
    println!("trained {} steps, final loss {:.3}", run.train_steps, report.final_loss);

    // 4. HEAPr: two forward passes + one backward pass on 32 calib samples
    let calib = train_split.sample(32, 0);
    let (scores, stats) = heapr_scores(&engine, &params, &calib)?;
    println!("calibrated on {} sequences (CE {:.3})", stats.n_sequences, stats.calib_ce);

    // 5. prune the 25% least-important atomic experts, globally ranked
    let plan = PrunePlan::from_scores(&scores, 0.25, Scope::Global);
    println!("pruned {:.1}% of atomic experts; activated-FLOPs reduction {:.1}%",
             plan.pruned_ratio() * 100.0,
             flops_reduction(&cfg, &plan.widths()) * 100.0);

    // 6. quality: held-out perplexity before/after
    let ppl0 = perplexity(&engine, &params, &ones_mask(&engine), &eval_split, 4)?;
    let ppl1 = perplexity(&engine, &params, &plan.mask(), &eval_split, 4)?;
    println!("perplexity: {ppl0:.3} -> {ppl1:.3} (ratio {:.3})", ppl1 / ppl0);

    // 7. serve one request through the width-bucketed coordinator
    let aligned = plan.bucket_aligned(&scores, cfg.blk_i);
    let mut server = Server::new(&engine, &params, Some(&aligned))?;
    let prompt = ByteTokenizer.encode("the ");
    let resp = server.serve_batch(&[Request::new(0, prompt, 24)])?;
    println!("generated: {:?} ({:.0}ms)",
             ByteTokenizer.decode(&resp[0].tokens), resp[0].latency_ms);
    Ok(())
}
