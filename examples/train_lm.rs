//! End-to-end training driver (EXPERIMENTS.md §E2E): train a MiniMoE LM
//! from scratch on the synthetic corpus, logging the loss curve, then
//! verify the trained model learned the grammar's structure (task suite
//! beats chance) and save the checkpoint.
//!
//!   cargo run --release --offline --example train_lm -- [--preset small]
//!     [--steps 300]

use anyhow::Result;
use heapr::config::RunConfig;
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::eval::tasks::{eval_tasks, mean_accuracy};
use heapr::eval::{ones_mask, perplexity};
use heapr::model::checkpoint::Checkpoint;
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::train::Trainer;
use heapr::util::args::Args;
use heapr::util::json::Json;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let preset = args.str("preset", "small");
    let steps = args.usize("steps", 300)?;
    let lr = args.f64("lr", 3e-3)?;
    args.finish()?;

    let engine = Engine::open(format!("artifacts/{preset}"))?;
    let cfg = engine.config().clone();
    let grammar = Grammar::standard();
    let docs = grammar.corpus("wiki", 0, 2_000_000);
    let (train_split, eval_split) = Split::from_docs(&docs, cfg.seq_len).train_eval(0.05);
    println!(
        "corpus: {} train chunks, {} eval chunks",
        train_split.n_chunks(),
        eval_split.n_chunks()
    );

    let mut params = ParamStore::init(&engine.manifest, 0);
    let run = RunConfig { train_steps: steps, lr, ..Default::default() };
    let report = Trainer::new(&engine).train(&mut params, &train_split, &run)?;

    println!("\nloss curve (step, total, ce):");
    for (s, l, c) in &report.curve {
        println!("  {s:>6} {l:8.4} {c:8.4}");
    }
    println!("wallclock: {:.1}s ({:.2} steps/s)",
             report.wallclock_s, steps as f64 / report.wallclock_s);

    let mask = ones_mask(&engine);
    let ppl = perplexity(&engine, &params, &mask, &eval_split, 8)?;
    println!("held-out perplexity: {ppl:.3} (uniform would be {})", cfg.vocab);

    let results = eval_tasks(&engine, &params, &mask, 32, 777)?;
    println!("\nzero-shot suite:");
    for r in &results {
        println!("  {:<12} {:.3}", r.kind.name(), r.accuracy);
    }
    println!("  {:<12} {:.3}", "Average", mean_accuracy(&results));

    let path = format!("runs/{preset}/model-{preset}.ckpt");
    Checkpoint {
        store: params,
        widths: None,
        meta: Json::obj(vec![
            ("steps", Json::n(steps as f64)),
            ("final_loss", Json::n(report.final_loss as f64)),
        ]),
    }
    .save(std::path::Path::new(&path))?;
    println!("\ncheckpoint saved to {path}");
    Ok(())
}
