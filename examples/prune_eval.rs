//! Pruning-ratio sweep over all criteria (the paper's core comparison,
//! condensed): HEAPr vs CAMERA-P vs magnitude vs random vs expert-drop at
//! several ratios, reporting held-out perplexity and FLOPs reduction.
//!
//!   cargo run --release --offline --example prune_eval -- [--preset tiny]
//!     [--steps 120] [--calib 64]

use anyhow::Result;
use heapr::baselines;
use heapr::config::RunConfig;
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::eval::{ones_mask, perplexity};
use heapr::heapr::{heapr_scores, PrunePlan, Scope};
use heapr::model::flops::flops_reduction;
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::train::Trainer;
use heapr::util::args::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let preset = args.str("preset", "tiny");
    let steps = args.usize("steps", 120)?;
    let n_calib = args.usize("calib", 64)?;
    args.finish()?;

    let engine = Engine::open(format!("artifacts/{preset}"))?;
    let cfg = engine.config().clone();
    let grammar = Grammar::standard();
    let docs = grammar.corpus("wiki", 0, 600_000);
    let (train_split, eval_split) = Split::from_docs(&docs, cfg.seq_len).train_eval(0.1);

    let mut params = ParamStore::init(&engine.manifest, 0);
    let run = RunConfig { train_steps: steps, lr: 4e-3, ..Default::default() };
    Trainer::new(&engine).train(&mut params, &train_split, &run)?;

    let calib = train_split.sample(n_calib.min(train_split.n_chunks()), 0);
    let (scores, stats) = heapr_scores(&engine, &params, &calib)?;
    let camera = baselines::camera_scores(&params, &stats, 0.5)?;
    let magnitude =
        baselines::magnitude_scores(&params, cfg.n_layers, cfg.n_experts, cfg.d_inter)?;
    let random = baselines::random_scores(cfg.n_layers, cfg.n_experts, cfg.d_inter, 7);

    let base = perplexity(&engine, &params, &ones_mask(&engine), &eval_split, 4)?;
    println!("baseline ppl {base:.3}\n");
    println!("{:<12} {:>6} {:>10} {:>10}", "method", "ratio", "ppl", "flops-rr");
    for ratio in [0.125, 0.25, 0.5, 0.75] {
        for (name, scores_t, scope) in [
            ("HEAPr", &scores, Scope::Global),
            ("CAMERA-P", &camera, Scope::Layerwise),
            ("Magnitude", &magnitude, Scope::Layerwise),
            ("Random", &random, Scope::Global),
        ] {
            let plan = PrunePlan::from_scores(scores_t, ratio, scope);
            let ppl = perplexity(&engine, &params, &plan.mask(), &eval_split, 4)?;
            let rr = flops_reduction(&cfg, &plan.widths());
            println!("{name:<12} {ratio:>6.3} {ppl:>10.3} {:>9.1}%", rr * 100.0);
        }
        println!();
    }
    Ok(())
}
