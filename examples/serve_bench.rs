//! Serving demo + throughput comparison (paper Appendix C shape): the same
//! request stream served by the dense model and by HEAPr-pruned models at
//! increasing ratios — atomic pruning must translate into real end-to-end
//! latency/throughput wins through the width-bucketed dispatch.
//!
//!   cargo run --release --offline --example serve_bench -- [--preset tiny]
//!     [--requests 12] [--new-tokens 12]

use anyhow::Result;
use heapr::config::RunConfig;
use heapr::coordinator::{Request, Residency, Server};
use heapr::data::corpus::Grammar;
use heapr::data::sampler::Split;
use heapr::data::tokenizer::ByteTokenizer;
use heapr::heapr::{heapr_scores, PrunePlan, Scope};
use heapr::model::store::ParamStore;
use heapr::runtime::Engine;
use heapr::train::Trainer;
use heapr::util::args::Args;
use heapr::util::rng::Pcg64;
use heapr::util::stats::percentile;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let preset = args.str("preset", "tiny");
    let n_req = args.usize("requests", 12)?;
    let new_tokens = args.usize("new-tokens", 12)?;
    let steps = args.usize("steps", 60)?;
    args.finish()?;

    let engine = Engine::open(format!("artifacts/{preset}"))?;
    let cfg = engine.config().clone();
    let grammar = Grammar::standard();
    let docs = grammar.corpus("wiki", 0, 400_000);
    let (train_split, _) = Split::from_docs(&docs, cfg.seq_len).train_eval(0.1);
    let mut params = ParamStore::init(&engine.manifest, 0);
    let run = RunConfig { train_steps: steps, lr: 4e-3, ..Default::default() };
    Trainer::new(&engine).train(&mut params, &train_split, &run)?;

    let calib = train_split.sample(32, 0);
    let (scores, _) = heapr_scores(&engine, &params, &calib)?;

    // fixed request stream
    let tok = ByteTokenizer;
    let mut rng = Pcg64::new(3);
    let requests: Vec<Request> = (0..n_req)
        .map(|i| {
            let doc = grammar.document(&mut rng, &[1.0; 6]);
            Request::new(i as u64, tok.encode(&doc[..doc.len().min(40)]), new_tokens)
        })
        .collect();

    println!("{:<22} {:>10} {:>12} {:>12} {:>10} {:>10}",
             "config", "tok/s", "p50 ms", "p99 ms", "widths", "B/step");
    for ratio in [0.0, 0.25, 0.5, 0.75] {
        let plan = if ratio == 0.0 {
            None
        } else {
            Some(PrunePlan::from_scores(&scores, ratio, Scope::Global)
                .bucket_aligned(&scores, cfg.blk_i))
        };
        for (residency, label) in
            [(Residency::Resident, "session"), (Residency::Legacy, "legacy")]
        {
            let mut server = Server::new(&engine, &params, plan.as_ref())?;
            server.set_residency(residency);
            let bucket = *cfg.serve_batches.last().unwrap();
            for chunk in requests.chunks(bucket) {
                server.serve_batch(chunk)?;
            }
            let m = &server.metrics;
            let mean_width: f64 = server.widths.widths.iter().flatten()
                .map(|&w| w as f64).sum::<f64>()
                / (cfg.n_layers * cfg.n_experts) as f64;
            println!("{:<22} {:>10.1} {:>12.1} {:>12.1} {:>10.1} {:>10.0}",
                     format!("ratio {ratio:.2} {label}"),
                     m.throughput_tps(),
                     percentile(&m.latencies_ms, 50.0),
                     percentile(&m.latencies_ms, 99.0),
                     mean_width,
                     m.upload_bytes_per_step());
        }
    }
    Ok(())
}
